"""Fault tolerance: checkpoint atomicity + resume, elastic resharding plan,
straggler detection, preemption handling, data pipeline determinism."""

import os
import signal
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import MemmapCorpus, SyntheticLM
from repro.ft import (
    CheckpointManager,
    PreemptionHandler,
    StragglerWatchdog,
    plan_elastic,
)


def _tree():
    return {
        "a": jnp.arange(12.0).reshape(3, 4),
        "nested": {"b": jnp.ones((2, 2), jnp.bfloat16)},
    }


def test_checkpoint_roundtrip(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    ckpt.save(1, t, extra={"step": 1})
    restored, extra = ckpt.restore(t)
    assert extra["step"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_keep_last_k_and_latest(tmp_path):
    ckpt = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in [1, 2, 3, 4]:
        ckpt.save(s, t)
    assert ckpt.all_steps() == [3, 4]
    assert ckpt.latest_step() == 4


def test_checkpoint_ignores_uncommitted(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    t = _tree()
    ckpt.save(5, t)
    # simulate a torn write: step dir without COMMITTED marker
    bad = tmp_path / "step_000000009"
    (bad / "arrays").mkdir(parents=True)
    (bad / "manifest.json").write_text("{}")
    assert ckpt.latest_step() == 5


def test_checkpoint_interrupted_save_restores_previous(tmp_path):
    """Crash-safety (DESIGN.md §11): a save torn mid-write (arrays +
    manifest on disk, COMMITTED never written — the kill -9 window) must
    leave the PREVIOUS committed step as the restore target, with its
    data intact."""
    ckpt = CheckpointManager(tmp_path)
    t = _tree()
    ckpt.save(1, t, extra={"segment": 1})
    # torn step 2: everything except the COMMITTED marker
    t2 = jax.tree.map(lambda x: x * 7, t)
    ckpt.save(2, t2, extra={"segment": 2})
    (tmp_path / "step_000000002" / "COMMITTED").unlink()
    assert ckpt.latest_step() == 1
    restored, extra = ckpt.restore(t)
    assert extra["segment"] == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
    # a staging dir abandoned mid-rename is never mistaken for a step
    stray = tmp_path / "step_000000003.tmp" / "arrays"
    stray.mkdir(parents=True)
    assert ckpt.latest_step() == 1
    # and the next real save recovers cleanly past both
    ckpt.save(3, t2, extra={"segment": 3})
    assert ckpt.latest_step() == 3
    _, extra3 = ckpt.restore(t2)
    assert extra3["segment"] == 3


def test_checkpoint_gc_skips_uncommitted(tmp_path):
    """keep-last-k GC counts only COMMITTED steps: torn dirs neither age
    out good checkpoints nor survive as restore candidates."""
    ckpt = CheckpointManager(tmp_path, keep=2)
    t = _tree()
    for s in (1, 2):
        ckpt.save(s, t)
    bad = tmp_path / "step_000000005"
    (bad / "arrays").mkdir(parents=True)
    (bad / "manifest.json").write_text("{}")
    ckpt.save(6, t)
    assert ckpt.all_steps() == [2, 6]


def test_checkpoint_solver_state_restores_onto_mesh(tmp_path):
    """The elastic-restart path: a solver ``PaddedState`` checkpointed on
    one process restores onto a DIFFERENT mesh shape — leaves are stored
    as full logical arrays and device_put onto the target shardings."""
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.core import from_least_squares_batch, prepare_padded_solve

    B, n, d = 4, 64, 8
    ks = jax.random.split(jax.random.PRNGKey(0), B)
    A = jnp.stack([jax.random.normal(k, (n, d)) / np.sqrt(n) for k in ks])
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
    q = from_least_squares_batch(A, Y, 0.1)
    keys = jax.random.split(jax.random.PRNGKey(42), B)
    _, st = prepare_padded_solve(q, keys, m_max=16)
    tree = st._asdict()

    ckpt = CheckpointManager(tmp_path)
    ckpt.save(1, tree, extra={"segment": 1})

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    shardings = jax.tree.map(
        lambda _: NamedSharding(mesh, P()), tree)
    restored, extra = ckpt.restore(tree, shardings=shardings)
    assert extra["segment"] == 1
    for key, leaf in restored.items():
        assert leaf.sharding.mesh.shape == mesh.shape, key
        np.testing.assert_array_equal(np.asarray(leaf),
                                      np.asarray(tree[key]), err_msg=key)


def test_checkpoint_async(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(7, _tree(), blocking=False)
    ckpt.wait()
    assert ckpt.latest_step() == 7


def test_checkpoint_shape_mismatch_raises(tmp_path):
    ckpt = CheckpointManager(tmp_path)
    ckpt.save(1, _tree())
    wrong = {"a": jnp.zeros((5, 4)), "nested": {"b": jnp.ones((2, 2))}}
    with pytest.raises(ValueError):
        ckpt.restore(wrong)


def test_elastic_plan_shrink():
    """512 → 384 live devices: mesh shrinks, global batch preserved."""
    plan = plan_elastic(global_batch=256, n_live_devices=384)
    assert plan.mesh.size <= 384
    dp = 1
    for a in plan.mesh.axis_names:
        if a != "model":
            dp *= plan.mesh.shape[a]
    assert 256 % dp == 0
    assert plan.per_device_batch * dp == 256


def test_straggler_watchdog_flags():
    flagged = []
    wd = StragglerWatchdog(factor=2.0, patience=2,
                           on_flag=lambda h, t: flagged.append(h))
    for _ in range(20):
        wd.record(0.1, host="h0")
    assert not flagged
    wd.record(0.5, host="h1")
    wd.record(0.5, host="h1")
    assert flagged == ["h1"]
    # recovery resets the counter
    wd2 = StragglerWatchdog(factor=2.0, patience=2)
    for _ in range(10):
        wd2.record(0.1)
    wd2.record(0.5)
    wd2.record(0.1)
    wd2.record(0.5)
    assert not wd2.flagged


def test_preemption_handler():
    with PreemptionHandler(signals=(signal.SIGUSR1,)) as p:
        assert not p.should_stop
        os.kill(os.getpid(), signal.SIGUSR1)
        time.sleep(0.05)
        assert p.should_stop


def test_synthetic_data_deterministic_resume():
    d1 = SyntheticLM(vocab=100, batch=2, seq_len=8, seed=3)
    batches = [next(d1) for _ in range(5)]
    st = d1.state()
    nxt = next(d1)
    d2 = SyntheticLM(vocab=100, batch=2, seq_len=8, seed=3)
    d2.restore(st)
    np.testing.assert_array_equal(next(d2)["tokens"], nxt["tokens"])


def test_memmap_corpus(tmp_path):
    data = np.arange(10_000, dtype=np.uint16) % 521
    f = tmp_path / "toks.bin"
    data.tofile(f)
    c = MemmapCorpus(str(f), batch=4, seq_len=32)
    b = next(c)
    assert b["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])
    # determinism across restore
    st = c.state()
    b2 = next(c)
    c.restore(st)
    np.testing.assert_array_equal(next(c)["tokens"], b2["tokens"])


def test_train_launcher_resume(tmp_path):
    """End-to-end: train 20 steps, 'crash', resume to 30 — loss continuous."""
    from repro.launch.train import main

    ckpt_dir = str(tmp_path / "ck")
    main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "20",
          "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt_dir,
          "--save-every", "10", "--log-every", "100"])
    main(["--arch", "qwen2-0.5b", "--reduced", "--steps", "30",
          "--batch", "2", "--seq", "32", "--ckpt-dir", ckpt_dir,
          "--save-every", "10", "--log-every", "100"])
    mgr = CheckpointManager(ckpt_dir)
    assert mgr.latest_step() == 30
