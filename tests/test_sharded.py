"""Sharded one-touch level-Gram providers + multi-device padded engine
(DESIGN.md §5): block-sketch normalization regression, sharded providers
vs the single-device BlockEmulationProvider reference, K=8 engine vs
single-device agreement, collective inventory (exactly one psum in the
precompute), and the serving satellites (vmapped pack keys, ν > 0 guard,
SRHT row-sampling laws).

Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 (the test_dist.py
pattern) so the main pytest process keeps the real device view;
single-device satellites run in-process. CI additionally runs this module
as its own forced-8-device job including the slow cases.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.level_grams import BlockEmulationProvider, get_provider
from repro.core.quadratic import Quadratic
from repro.core.status import SolveStatus
from repro.serve.solver_service import ShapeClass, SolverService


def _run_subprocess(code: str) -> str:
    import os
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(root / "src")}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=str(root), timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# block_sketch_gram normalization (the /√K regression)
# ---------------------------------------------------------------------------

def test_block_sketch_gram_scaling_regression():
    """E[(SA)ᵀSA] must equal AᵀA with NO per-shard rescale: per-shard
    Gaussian entries are already N(0, 1/m) and SJLT/SRHT blocks satisfy
    E[S_kᵀS_k] = I. The pre-fix /√n_shards rescale shrank the mean Gram
    to AᵀA/K (relative error ≈ (K−1)/K ≈ 0.88 at K=8, vs ≈ 0.12 for the
    corrected code at this sample count — the 0.35 threshold splits them
    decisively), and an IHS solve under the K-weak preconditioner
    overshoots its fixed 1−ρ step and diverges to NaN."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import from_least_squares, direct_solve
        from repro.core.distributed import block_sketch_gram
        from repro.core.precond import factorize
        from repro.core.solvers import run_fixed

        mesh = jax.make_mesh((8,), ("data",))
        n, d, m, R = 512, 32, 128, 16
        A = jax.random.normal(jax.random.PRNGKey(0), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(1), (n,))
        q = from_least_squares(A, y, 0.1)
        x_star = direct_solve(q)
        G = np.asarray(A.T @ A)

        for kind in ("gaussian", "sjlt", "srht"):
            f = jax.jit(lambda key: block_sketch_gram(A, key, kind, m, mesh))
            acc = np.zeros((d, d))
            for r in range(R):
                SA = np.asarray(f(jax.random.PRNGKey(100 + r)))
                acc += SA.T @ SA
            rel = np.linalg.norm(acc / R - G) / np.linalg.norm(G)
            assert rel < 0.35, (kind, rel)   # pre-fix: ≈ 0.88

            # unsharded-rate convergence: IHS's fixed 1−ρ step requires a
            # correctly scaled H_S (pre-fix it diverges to NaN/inf)
            SA = f(jax.random.PRNGKey(7))
            P = factorize(SA, q.nu, q.lam_diag)
            x, trace = run_fixed(q, P, jnp.zeros((d,)), method="ihs",
                                 iters=25, rho=0.5)
            err = float(jnp.linalg.norm(x - x_star) / jnp.linalg.norm(x_star))
            assert np.isfinite(np.asarray(trace)).all(), kind
            assert err < 1e-3, (kind, err)
        print("SCALING_OK")
    """)
    assert "SCALING_OK" in out


# ---------------------------------------------------------------------------
# shard_level_grams: all families vs the replicated reference
# ---------------------------------------------------------------------------

def test_shard_level_grams_match_replicated_reference():
    """For all 4 families × {per-problem, shared} A: the shard_map one-touch
    pass with fold_in(key, shard) randomness equals the single-device
    BlockEmulationProvider (identical per-shard keys), the precompute
    jaxpr lowers exactly ONE psum whose operand is the (L, B, d, d) Gram
    stack, and no global-row-count intermediate exists per shard."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.analysis.audit import collect_eqns, has_intermediate_of_shape
        from repro.core.adaptive_padded import doubling_ladder
        from repro.core.distributed import shard_level_grams, shard_quadratic
        from repro.core.level_grams import (PADDED_SKETCHES,
                                            BlockEmulationProvider,
                                            get_provider)
        from repro.core.quadratic import from_least_squares_batch

        mesh = jax.make_mesh((8,), ("data",))
        B, n, d, m_max, K = 3, 512, 8, 24, 8     # ladder has a non-pow2 cap
        ladder = doubling_ladder(m_max)
        A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d)) / np.sqrt(n)
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        q_per = from_least_squares_batch(A, Y, jnp.asarray([0.1, 0.2, 0.3]))
        q_sh = from_least_squares_batch(A[0], Y, 0.1)
        assert q_sh.shared_A and not q_per.shared_A

        for sketch in PADDED_SKETCHES:
            prov = get_provider(sketch)
            emu = BlockEmulationProvider(sketch, K)
            for q in (q_per, q_sh):
                got = np.asarray(shard_level_grams(prov, keys, q, ladder,
                                                   mesh))
                want = np.asarray(emu.level_grams(
                    emu.sample(keys, m_max, q.n, jnp.float32), q, ladder))
                rel = (np.linalg.norm(got - want)
                       / (np.linalg.norm(want) + 1e-30))
                assert rel < 1e-5, (sketch, q.shared_A, rel)

                jx = jax.make_jaxpr(
                    lambda q, ks: shard_level_grams(prov, ks, q, ladder,
                                                    mesh))(q, keys)
                ps = collect_eqns(jx, "psum")
                assert len(ps) == 1, (sketch, len(ps))
                L = len(ladder)
                assert tuple(ps[0].outvars[0].aval.shape) == (L, B, d, d)
                # the communicated payload is the Gram stack, and no GLOBAL
                # dense sketch (B, m_max, n) exists anywhere; the streamed
                # family never materializes even the LOCAL dense sketch
                assert not has_intermediate_of_shape(jx, (B, m_max, n))
                if sketch == "gaussian":
                    assert not has_intermediate_of_shape(
                        jx, (B, m_max, n // K))

            # per-shard key independence: distinct shards draw distinct
            # randomness (fold_in(key, k)), so their partial Grams differ
            sh = emu.sample(keys, m_max, n, jnp.float32)["shards"]
            g0 = np.asarray(get_provider(sketch).level_grams(
                sh[0], from_least_squares_batch(
                    A[:, : n // K], Y[:, : n // K],
                    jnp.asarray([0.1, 0.2, 0.3])), ladder))
            g1 = np.asarray(get_provider(sketch).level_grams(
                sh[1], from_least_squares_batch(
                    A[:, : n // K], Y[:, : n // K],
                    jnp.asarray([0.1, 0.2, 0.3])), ladder))
            assert not np.allclose(g0, g1), sketch
        print("PROVIDERS_OK")
    """)
    assert "PROVIDERS_OK" in out


def test_weighted_shard_level_grams_and_gram():
    """GLM-layer sharded path (DESIGN.md §8): with row_weights the one-psum
    ladder precompute equals the weighted BlockEmulationProvider (identical
    per-shard keys — W is row-diagonal, so it splits over row blocks
    exactly like A), shard_weighted_gram psums to AᵀWA, and a weighted
    sharded engine solve matches the single-device weighted solve."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.adaptive_padded import (doubling_ladder,
                                                padded_adaptive_solve_batched)
        from repro.core.distributed import (shard_level_grams,
                                            shard_quadratic,
                                            shard_weighted_gram)
        from repro.core.level_grams import BlockEmulationProvider, get_provider
        from repro.core.quadratic import direct_solve, from_least_squares_batch

        mesh = jax.make_mesh((8,), ("data",))
        B, n, d, m_max, K = 3, 512, 8, 24, 8
        ladder = doubling_ladder(m_max)
        A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d)) / np.sqrt(n)
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        w = jax.random.uniform(jax.random.PRNGKey(2), (B, n),
                               minval=0.05, maxval=2.0)
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        qw = from_least_squares_batch(A, Y, jnp.asarray([0.1, 0.2, 0.3])
                                      ).with_row_weights(w)
        qd = shard_quadratic(qw, mesh)
        for sketch in ("gaussian", "sjlt", "srht"):
            got = np.asarray(shard_level_grams(get_provider(sketch), keys,
                                               qd, ladder, mesh))
            emu = BlockEmulationProvider(sketch, K)
            want = np.asarray(emu.level_grams(
                emu.sample(keys, m_max, n, jnp.float32), qw, ladder))
            rel = np.linalg.norm(got - want) / (np.linalg.norm(want) + 1e-30)
            assert rel < 1e-5, (sketch, rel)
        G = np.asarray(shard_weighted_gram(qd, mesh))
        G_ref = np.asarray(jnp.einsum("bn,bnd,bne->bde", w, A, A))
        assert np.linalg.norm(G - G_ref) / np.linalg.norm(G_ref) < 1e-5
        x_sh, s_sh = padded_adaptive_solve_batched(
            qd, keys, m_max=m_max, method="pcg", sketch="gaussian",
            max_iters=100, tol=1e-12, mesh=mesh)
        x_star = np.asarray(direct_solve(qw))
        rel = np.linalg.norm(np.asarray(x_sh) - x_star) / np.linalg.norm(x_star)
        assert rel < 1e-4, rel
        print("WEIGHTED_SHARDED_OK")
    """)
    assert "WEIGHTED_SHARDED_OK" in out


# ---------------------------------------------------------------------------
# K=8 engine vs single device (acceptance)
# ---------------------------------------------------------------------------

def test_sharded_engine_matches_single_device():
    """The sharded engine on a K=8 mesh agrees with single-device solves:
    x to ≤1e-5 against BOTH the plain single-device engine (different
    sketch law, same optimum) and the BlockEmulationProvider run
    (identical per-shard keys — certificates δ̃ within 2×, schedules in
    fact identical)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.adaptive_padded import padded_adaptive_solve_batched
        from repro.core.distributed import sharded_padded_solve
        from repro.core.level_grams import BlockEmulationProvider
        from repro.core.quadratic import direct_solve, from_least_squares_batch

        mesh = jax.make_mesh((8,), ("data",))
        B, n, d, m_max = 4, 512, 16, 64
        A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d)) / np.sqrt(n)
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        q = from_least_squares_batch(A, Y, jnp.asarray([0.3, 0.4, 0.5, 0.6]))
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        emu = BlockEmulationProvider("gaussian", 8)
        rel = lambda a, b: float(jnp.linalg.norm(a - b)
                                 / (jnp.linalg.norm(b) + 1e-30))

        # deep convergence (floor-polish): x agreement across all three
        kw = dict(m_max=m_max, method="pcg", tol=1e-12, max_iters=200)
        x_sh, _ = sharded_padded_solve(q, keys, mesh, sketch="gaussian",
                                       **kw)
        x_1, _ = padded_adaptive_solve_batched(q, keys, sketch="gaussian",
                                               **kw)
        x_emu, _ = padded_adaptive_solve_batched(q, keys, sketch=emu, **kw)
        X = direct_solve(q)
        for i in range(B):
            assert rel(x_sh[i], x_1[i]) <= 1e-5, i
            assert rel(x_sh[i], x_emu[i]) <= 1e-5, i
            assert rel(x_sh[i], X[i]) <= 1e-4, i

        # certificate agreement where δ̃ is set by the stopping rule, not
        # f32 floor noise: identical per-shard keys ⇒ identical trajectories
        # (same doubling schedules, δ̃ within 2× — in practice within fp)
        kw = dict(m_max=m_max, method="pcg", tol=1e-8, max_iters=200)
        _, s_sh = sharded_padded_solve(q, keys, mesh, sketch="gaussian",
                                       **kw)
        _, s_emu = padded_adaptive_solve_batched(q, keys, sketch=emu, **kw)
        for i in range(B):
            ratio = float(s_sh["dtilde"][i]) / max(float(s_emu["dtilde"][i]),
                                                   1e-300)
            assert 0.5 <= ratio <= 2.0, (i, ratio)
        assert np.array_equal(np.asarray(s_sh["m_final"]),
                              np.asarray(s_emu["m_final"]))
        print("ENGINE_OK")
    """)
    assert "ENGINE_OK" in out


@pytest.mark.slow
def test_sharded_solver_service_end_to_end():
    """SolverService(mesh=...) solves real requests on an 8-device mesh and
    matches the dense direct solve; slot utilization is reported."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import direct_solve, from_least_squares
        from repro.serve.solver_service import ShapeClass, SolverService

        mesh = jax.make_mesh((8,), ("data",))
        svc = SolverService(batch_size=4, sketch="gaussian", tol=1e-12,
                            mesh=mesh,
                            shape_classes=(ShapeClass(256, 32, 64),
                                           ShapeClass(1024, 64, 128)))
        rng = np.random.default_rng(0)
        reqs = []
        for i in range(5):
            n = int(rng.integers(64, 900))
            d = int(rng.integers(8, 60))
            A = jax.random.normal(jax.random.PRNGKey(i), (n, d)) / np.sqrt(n)
            y = jax.random.normal(jax.random.PRNGKey(50 + i), (n,))
            nu = float(rng.uniform(0.1, 0.4))
            reqs.append((svc.submit(A, y, nu), A, y, nu))
        sols = svc.flush()
        assert len(sols) == 5
        for rid, A, y, nu in reqs:
            s = sols[rid]
            x_star = direct_solve(from_least_squares(A, y, nu))
            r = float(jnp.linalg.norm(s.x - x_star)
                      / jnp.linalg.norm(x_star))
            assert r < 1e-4, (rid, r)
        assert 0.0 < svc.slot_utilization() <= 1.0
        print("SERVICE_OK", svc.slot_utilization())
    """)
    assert "SERVICE_OK" in out


# ---------------------------------------------------------------------------
# In-process satellites (single device)
# ---------------------------------------------------------------------------

def test_srht_row_sampling_laws():
    """ops.srht_sketch samples rows WITHOUT replacement (classical SRHT:
    m = n_pad gives all-distinct rows), while SRHTProvider's ladder stream
    is i.i.d. WITH replacement (duplicates near-certain at m_max = n_pad) —
    the documented difference both docstrings pin."""
    from repro.kernels import ops

    n = 60                                   # n_pad = 64
    n_pad = 64
    I = jnp.eye(n, dtype=jnp.float32)
    S = np.asarray(ops.srht_sketch(I, jax.random.PRNGKey(0), n_pad))
    # distinct Hadamard rows (same sign diagonal) → pairwise distinct rows
    uniq = np.unique(np.round(S, 5), axis=0)
    assert uniq.shape[0] == n_pad, uniq.shape

    prov = get_provider("srht")
    dup = 0
    for seed in range(5):
        keys = jax.random.split(jax.random.PRNGKey(seed), 1)
        rows = np.asarray(prov.sample(keys, n_pad, n, jnp.float32)["rows"])[0]
        assert rows.shape == (n_pad,)
        dup += int(len(np.unique(rows)) < n_pad)
    assert dup == 5, "i.i.d. row stream should collide at m_max = n_pad"


def test_service_rejects_nu_zero():
    """ν = 0 padded problems NaN-poison certificates inside the pre-guard
    engine (demonstrated with guards=False); the DESIGN.md §9 guards turn
    that into a finite iterate with a truthful LEVEL_INVALID verdict, and
    SolverService.submit still rejects ν = 0 up front so neither failure
    shape reaches flush."""
    # the guarded failure: zero-padded coordinate + ν = 0 ⇒ H_S singular
    n, d = 32, 4
    A = np.array(jax.random.normal(jax.random.PRNGKey(0), (1, n, d)),
                 np.float32)
    A[:, :, -1] = 0.0                        # a padded (all-zero) column
    b = np.zeros((1, d), np.float32)
    b[0, :d - 1] = 1.0
    q = Quadratic(A=jnp.asarray(A), b=jnp.asarray(b),
                  nu=jnp.zeros((1,)), lam_diag=jnp.ones((1, d)),
                  batched=True)
    _, stats = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(1), m_max=8, method="pcg", guards=False)
    assert not np.isfinite(np.asarray(stats["dtilde"])).all()
    x_g, stats_g = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(1), m_max=8, method="pcg")
    assert np.isfinite(np.asarray(x_g)).all()
    assert np.asarray(stats_g["status"])[0] == int(SolveStatus.LEVEL_INVALID)

    svc = SolverService(shape_classes=(ShapeClass(64, 8, 16),), batch_size=2)
    A1 = jnp.ones((32, 4)) / 8.0
    y1 = jnp.ones((32,))
    for bad in (0.0, -1.0, float("nan"), float("inf")):
        with pytest.raises(ValueError):
            svc.submit(A1, y1, bad)
    rid = svc.submit(A1, y1, 0.5)            # valid request still flows
    sol = svc.flush()[rid]
    assert np.isfinite(sol.delta_tilde)
    assert np.isfinite(np.asarray(sol.x)).all()


def test_pack_vmapped_keys_and_padded_slots():
    """_pack computes all slot keys in ONE vmapped fold_in: real slot i
    carries fold_in(base, req_id); padded slot s carries the reserved
    top-of-range fold_in(base, 2³²−1−s) — all B keys pairwise distinct, so
    a padded slot can never alias a real request's sketch."""
    svc = SolverService(shape_classes=(ShapeClass(64, 8, 16),), batch_size=4)
    for _ in range(2):
        svc.submit(jnp.ones((32, 4)) / 8.0, jnp.ones((32,)), 0.3)
    cls = svc.shape_classes[0]
    reqs = svc._queues[cls]
    q, keys = svc._pack(cls, reqs)
    keys = np.asarray(keys)
    assert keys.shape[0] == 4
    for i, r in enumerate(reqs):
        want = np.asarray(jax.random.fold_in(svc._base_key, r.req_id))
        np.testing.assert_array_equal(keys[i], want)
    for s in (2, 3):
        want = np.asarray(jax.random.fold_in(svc._base_key, 2**32 - 1 - s))
        np.testing.assert_array_equal(keys[s], want)
    flat = [tuple(k.ravel().tolist()) for k in keys]
    assert len(set(flat)) == 4


def test_block_emulation_provider_single_device():
    """The emulation provider is the replicated reference: K=2 shard sum
    over row halves with folded keys, for every family; get_provider
    passes instances through; non-divisible n is rejected."""
    from repro.core.quadratic import from_least_squares_batch

    B, n, d, m_max = 2, 64, 4, 8
    A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d))
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
    q = from_least_squares_batch(A, Y, 0.1)
    keys = jax.random.split(jax.random.PRNGKey(2), B)
    ladder = (1, 2, 4, 8)
    for sketch in ("gaussian", "sjlt", "srht"):
        emu = BlockEmulationProvider(sketch, 2)
        assert get_provider(emu) is emu
        got = np.asarray(emu.level_grams(
            emu.sample(keys, m_max, n, jnp.float32), q, ladder))
        inner = get_provider(sketch)
        want = 0
        for k in range(2):
            fk = jax.vmap(lambda kb: jax.random.fold_in(kb, k))(keys)
            qk = from_least_squares_batch(
                A[:, k * (n // 2):(k + 1) * (n // 2)],
                Y[:, k * (n // 2):(k + 1) * (n // 2)], 0.1)
            want = want + np.asarray(inner.level_grams(
                inner.sample(fk, m_max, n // 2, jnp.float32), qk, ladder))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6,
                                   err_msg=sketch)
    with pytest.raises(ValueError):
        BlockEmulationProvider("gaussian", 2).sample(keys, m_max, 63,
                                                     jnp.float32)


def test_pod_scale_class_gated_on_mesh():
    """The n=65536 tail class is only a default for sharded services: a
    mesh-less service keeps failing fast on requests no device can hold,
    while SolverService(mesh=...) buckets them."""
    svc = SolverService()
    assert max(c.n for c in svc.shape_classes) == 16384
    with pytest.raises(ValueError):
        svc.bucket_for(20000, 64)
    mesh = jax.make_mesh((1,), ("data",))
    svc_sh = SolverService(mesh=mesh)
    assert svc_sh.bucket_for(20000, 64).n == 65536


def test_ridge_flags():
    """--ridge-batch is its own flag (default 16, not the LM --batch=4)
    and --mesh selects the data-shard count."""
    from repro.launch.serve import build_parser

    ap = build_parser()
    args = ap.parse_args(["--ridge"])
    assert args.ridge_batch == 16 and args.mesh == 0 and args.batch == 4
    args = ap.parse_args(["--ridge", "--ridge-batch", "8", "--mesh", "4"])
    assert args.ridge_batch == 8 and args.mesh == 4
