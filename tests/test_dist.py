"""Distributed tests. Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps the real (1-)device view."""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compress import init_ef, compress_tree


def _run_subprocess(code: str) -> str:
    env = {
        "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
        "PYTHONPATH": "src",
        "PATH": "/usr/bin:/bin",
        "HOME": "/root",
    }
    import os
    env = {**os.environ, **env}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd="/root/repo", timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


def test_gradient_compression_error_feedback():
    """EF-int8 SGD tracks uncompressed SGD on a quadratic."""
    key = jax.random.PRNGKey(0)
    H = jax.random.normal(key, (16, 16))
    H = H @ H.T / 16 + jnp.eye(16)
    b = jax.random.normal(jax.random.PRNGKey(1), (16,))
    grad = lambda x: H @ x - b

    x_ref = jnp.zeros(16)
    x_c = jnp.zeros(16)
    ef = init_ef(x_c)
    lr = 0.05
    for _ in range(150):
        x_ref = x_ref - lr * grad(x_ref)
        g_hat, ef = compress_tree(grad(x_c), ef)
        x_c = x_c - lr * g_hat
    rel = float(jnp.linalg.norm(x_c - x_ref) / jnp.linalg.norm(x_ref))
    assert rel < 0.01, f"EF-compressed trajectory diverged: {rel}"


def test_int8_quantization_bounds():
    x = jax.random.normal(jax.random.PRNGKey(2), (1000,)) * 5
    ef = init_ef(x)
    g_hat, ef2 = compress_tree(x, ef)
    # quantization error bounded by scale = max|x|/127
    err = jnp.max(jnp.abs(g_hat - x))
    assert float(err) <= float(jnp.max(jnp.abs(x))) / 127.0 * 1.01
    # error feedback holds the residual
    np.testing.assert_allclose(np.asarray(ef2.residual),
                               np.asarray(x - g_hat), rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_distributed_block_sketch_8dev():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import from_least_squares, direct_solve
        from repro.core.distributed import shard_quadratic, distributed_sketch_and_factorize
        from repro.core.solvers import run_fixed
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        A = jax.random.normal(jax.random.PRNGKey(0), (512, 64)) / np.sqrt(512)
        y = jax.random.normal(jax.random.PRNGKey(1), (512,))
        q = from_least_squares(A, y, 0.1)
        x_star = direct_solve(q)
        qd = shard_quadratic(q, mesh)
        with mesh:
            for kind in ["gaussian", "sjlt", "srht"]:
                P = distributed_sketch_and_factorize(qd, jax.random.PRNGKey(2), kind, 256, mesh)
                x, _ = run_fixed(qd, P, jnp.zeros((64,)), method="pcg", iters=25, rho=0.5)
                err = float(jnp.linalg.norm(x - x_star)/jnp.linalg.norm(x_star))
                assert err < 1e-3, (kind, err)
        print("DIST_OK")
    """)
    assert "DIST_OK" in out


@pytest.mark.slow
def test_sharded_train_step_matches_single_device():
    """The same train step on a (4,2) mesh and on 1 device produces the
    same loss and (numerically close) parameters."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.models import init_params
        from repro.dist.sharding import param_specs, input_specs_for
        from repro.train import AdamWConfig, TrainConfig, init_opt_state
        from repro.train.step import make_train_step

        cfg = get_config("qwen2-0.5b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
        tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10),
                           num_microbatches=2, compute_dtype=jnp.float32)
        toks = jax.random.randint(jax.random.PRNGKey(1), (8, 17), 0, cfg.vocab)
        batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
                 "mask": jnp.ones((8, 16), jnp.float32)}

        # single device
        step1 = jax.jit(make_train_step(cfg, tcfg))
        p1, o1, m1 = step1(params, init_opt_state(params), batch)

        # sharded
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        spec = param_specs(cfg, params, mesh)
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s), spec)
        params_d = jax.device_put(params, p_sh)
        with mesh:
            step2 = jax.jit(make_train_step(cfg, tcfg))
            p2, o2, m2 = step2(params_d, init_opt_state(params_d), batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
        d = max(float(jnp.max(jnp.abs(a - b)))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 1e-4, d
        print("SHARD_OK", float(m1["loss"]), float(m2["loss"]))
    """)
    assert "SHARD_OK" in out


@pytest.mark.slow
def test_decode_step_sharded_matches():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding
        from repro.configs import get_config
        from repro.models import init_params, init_cache
        from repro.dist.sharding import param_specs, cache_specs
        from repro.serve.step import decode_step

        cfg = get_config("qwen2-7b").reduced()
        params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
        cache = init_cache(cfg, 8, 32, dtype=jnp.float32)
        tok = jax.random.randint(jax.random.PRNGKey(1), (8, 1), 0, cfg.vocab)
        lg1, _ = decode_step(params, cfg, tok, cache, jnp.asarray(0, jnp.int32),
                             compute_dtype=jnp.float32)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            param_specs(cfg, params, mesh, fsdp=False))
        c_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                            cache_specs(cfg, cache, mesh))
        with mesh:
            lg2, _ = decode_step(jax.device_put(params, p_sh), cfg, tok,
                                 jax.device_put(cache, c_sh),
                                 jnp.asarray(0, jnp.int32),
                                 compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2),
                                   rtol=2e-4, atol=2e-4)
        print("DECODE_OK")
    """)
    assert "DECODE_OK" in out
