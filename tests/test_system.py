"""End-to-end system behaviour: the paper's pipeline solves real problems
faster (in iterations / flops) than baselines; adaptive beats non-adaptive;
launcher integration."""

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    from_least_squares,
)


def test_adaptive_pcg_fewer_hvp_than_cg(ridge_problem):
    """The paper's headline: adaptive PCG needs far fewer H·v passes than
    CG on ill-conditioned problems (each PCG iter = 1 hvp, like CG)."""
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    res = adaptive_solve(
        q, AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=500,
                          tol=1e-10),
        key=jax.random.PRNGKey(0),
    )
    err_target = float(jnp.linalg.norm(res.x - x_star) /
                       jnp.linalg.norm(x_star))
    # how many CG iterations to reach the same error?
    cg_iters = None
    for iters in [25, 50, 100, 200, 400, 800]:
        x_cg, _ = cg_solve(q, jnp.zeros((q.d,)), iters=iters)
        if float(jnp.linalg.norm(x_cg - x_star) /
                 jnp.linalg.norm(x_star)) <= max(err_target, 1e-6) * 1.5:
            cg_iters = iters
            break
    total_adaptive_hvp = res.iters + res.n_doublings
    assert cg_iters is None or total_adaptive_hvp < cg_iters, (
        f"adaptive used {total_adaptive_hvp} hvp vs CG {cg_iters}"
    )


def test_adaptive_smaller_sketch_than_2d(ridge_problem):
    """Final adaptive sketch ≪ the oblivious default m = 2d."""
    q = ridge_problem["q"]
    res = adaptive_solve(
        q, AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=200,
                          tol=1e-9),
        key=jax.random.PRNGKey(1),
    )
    assert res.m_final < 2 * q.d


def test_effective_dim_tracks_nu(ridge_problem):
    """Smaller ν ⇒ larger d_e ⇒ larger final sketch (paper Fig. 1 trend)."""
    q0 = ridge_problem["q"]
    finals = []
    for nu in [3e-1, 1e-2]:
        q = from_least_squares(q0.A, jnp.ones((q0.n,)), nu)
        res = adaptive_solve(
            q, AdaptiveConfig(method="pcg", sketch="gaussian",
                              max_iters=200, tol=1e-8),
            key=jax.random.PRNGKey(2),
        )
        finals.append(res.m_final)
    assert finals[1] >= finals[0]


def test_ridge_probe_pipeline():
    """Solver-on-backbone integration: fit a readout over model features
    by adaptive PCG and beat the zero init on held-out MSE."""
    from repro.configs import get_config
    from repro.models import init_params, forward

    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    B, S = 8, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    # features = final hidden states (use logits pre-head trick: forward
    # returns logits; instead extract by calling with identity head)
    logits, _ = forward(params, cfg, toks, compute_dtype=jnp.float32)
    feats = logits.reshape(B * S, -1)[:, : cfg.d_model]  # cheap proxy feats
    w_true = jax.random.normal(jax.random.PRNGKey(2), (cfg.d_model,)) / 8
    y = feats @ w_true + 0.01 * jax.random.normal(jax.random.PRNGKey(3),
                                                  (B * S,))
    q = from_least_squares(feats, y, nu=0.1)
    res = adaptive_solve(q, AdaptiveConfig(method="pcg", sketch="sjlt",
                                           max_iters=100, tol=1e-8),
                         key=jax.random.PRNGKey(4))
    pred = feats @ res.x
    mse = float(jnp.mean((pred - y) ** 2))
    base = float(jnp.mean(y ** 2))
    assert mse < 0.05 * base
