"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode on CPU (the TPU-target kernels' semantics).
Hypothesis property sweeps live in test_properties.py (optional dep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.kernels import ref
from repro.kernels.fwht import fwht_pallas
from repro.kernels.gaussian_gram import (
    gaussian_s_dense,
    gaussian_sa_pallas,
    gaussian_sa_ref,
)
from repro.kernels.sjlt import sjlt_pallas


@pytest.mark.parametrize("n", [8, 64, 512, 2048])
@pytest.mark.parametrize("d", [1, 7, 128, 130])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_matches_ref(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n * 31 + d), (n, d)).astype(dtype)
    got = fwht_pallas(x, interpret=True)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol,
        atol=tol * np.sqrt(n),
    )


def test_fwht_matches_dense_hadamard():
    n, d = 128, 9
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    H = ref.hadamard_dense(n)
    np.testing.assert_allclose(
        np.asarray(fwht_pallas(x, interpret=True)), np.asarray(H @ x),
        rtol=1e-4, atol=1e-4,
    )


def test_fwht_large_two_pass(monkeypatch):
    monkeypatch.setattr(ops, "_FWHT_VMEM_MAX_N", 64)
    for n in [128, 1024]:
        x = jax.random.normal(jax.random.PRNGKey(n), (n, 5))
        got = ops.fwht_large(x, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.fwht_ref(x)),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,m,br", [
    (512, 64, 32, 256), (1000, 37, 128, 128), (256, 300, 8, 64),
    (128, 16, 2048, 128),
])
def test_sjlt_kernel_matches_ref(n, d, m, br):
    A = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    rows = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(3), (n,), dtype=A.dtype)
    got = sjlt_pallas(A, rows, signs, m, interpret=True, block_rows=br)
    want = ref.sjlt_ref(A, rows, signs, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("shared", [False, True])
@pytest.mark.parametrize("n,d,m,chunk", [
    (300, 17, 24, 256), (1024, 64, 128, 512), (777, 5, 8, 256),
])
def test_gaussian_sa_kernel_matches_ref(shared, n, d, m, chunk):
    """Fused generate-and-multiply kernel (interpret mode = TPU semantics)
    vs the chunked scan oracle: identical sketch entries by construction,
    contraction to fp reduction error."""
    B = 3
    seeds = jnp.asarray([1, 77, 123456789], jnp.uint32)
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d) if shared
                          else (B, n, d))
    got = gaussian_sa_pallas(A, seeds, m, chunk_cols=chunk, interpret=True)
    want = gaussian_sa_ref(A, seeds, m)
    assert got.shape == (B, m, d)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_gaussian_sa_identity_recovers_sketch():
    """A = I makes the contraction exact: the kernel's in-VMEM tiles are
    bit-for-bit the counter-hash sketch that gaussian_s_dense materializes."""
    n = d = 64
    m = 24
    seeds = jnp.asarray([5, 6], jnp.uint32)
    out = gaussian_sa_pallas(jnp.eye(n), seeds, m, interpret=True)
    S = gaussian_s_dense(seeds, m, n)
    assert bool(jnp.all(out == S))


def test_gaussian_sketch_is_standard_normal():
    """Counter-hash + Box–Muller entries pass basic moment checks."""
    S = np.asarray(gaussian_s_dense(jnp.asarray([3], jnp.uint32), 256, 1024))
    assert abs(S.mean()) < 5e-3
    assert abs(S.std() - 1.0) < 5e-3
    assert abs((S**4).mean() - 3.0) < 0.05        # kurtosis of N(0,1)
    # distinct seeds decorrelate
    S2 = np.asarray(gaussian_s_dense(jnp.asarray([4], jnp.uint32), 256, 1024))
    corr = float(np.abs(np.corrcoef(S.ravel(), S2.ravel())[0, 1]))
    assert corr < 5e-3


@pytest.mark.parametrize("shared", [False, True])
def test_gaussian_sa_kernel_weighted_matches_ref(shared):
    """Weighted fused kernel (S·W^{1/2}·A with w^{1/2} scaling the S tile
    in VMEM) vs the weighted scan oracle vs the explicit W^{1/2}A
    materialization — all within fp reduction error."""
    B, n, d, m = 3, 700, 9, 16
    seeds = jnp.asarray([9, 10, 11], jnp.uint32)
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d) if shared
                          else (B, n, d))
    w = jax.random.uniform(jax.random.PRNGKey(1), (B, n),
                           minval=0.05, maxval=3.0)
    got = gaussian_sa_pallas(A, seeds, m, chunk_cols=256, interpret=True,
                             row_weights=w)
    want = gaussian_sa_ref(A, seeds, m, row_weights=w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    Aw = jnp.sqrt(w)[:, :, None] * (A[None] if shared else A)
    explicit = gaussian_sa_ref(Aw, seeds, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(explicit),
                               rtol=1e-3, atol=1e-4)


def test_fwht_kernel_fused_row_scale():
    """H·diag(s)·x fused in the kernel equals scaling then transforming."""
    n, d = 256, 20
    x = jax.random.normal(jax.random.PRNGKey(2), (n, d))
    s = jax.random.normal(jax.random.PRNGKey(3), (n,))
    got = fwht_pallas(x, interpret=True, row_scale=s)
    want = ref.fwht_ref(x * s[:, None])
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_sjlt_weighted_fold_matches_explicit():
    """ops.sjlt_apply with row_weights == the unweighted sketch of the
    materialized W^{1/2}A (one signed nonzero per column ⇒ folding w^{1/2}
    into the signs is exact)."""
    n, d, m = 300, 11, 32
    A = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    rows = jax.random.randint(jax.random.PRNGKey(5), (n,), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(6), (n,),
                                  dtype=A.dtype)
    w = jax.random.uniform(jax.random.PRNGKey(7), (n,), minval=0.1,
                           maxval=2.0)
    got = ops.sjlt_apply(A, rows, signs, m, row_weights=w)
    want = ref.sjlt_ref(jnp.sqrt(w)[:, None] * A, rows, signs, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_srht_sketch_weighted():
    """ops.srht_sketch(row_weights=w) sketches W^{1/2}A exactly (the fold
    into the sign flip changes no randomness)."""
    n, d, m = 200, 8, 64
    A = jax.random.normal(jax.random.PRNGKey(8), (n, d))
    w = jax.random.uniform(jax.random.PRNGKey(9), (n,), minval=0.1,
                           maxval=2.0)
    key = jax.random.PRNGKey(10)
    got = ops.srht_sketch(A, key, m, use_pallas=True, interpret=True,
                          row_weights=w)
    want = ops.srht_sketch(jnp.sqrt(w)[:, None] * A, key, m,
                           use_pallas=True, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# compute-dtype modes (DESIGN.md §10) — ids carry "bf16"/"int8" so the CI
# dtype matrix can select exactly these with -k
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compute_dtype", ["bf16", "int8"])
@pytest.mark.parametrize("shared", [False, True])
def test_gaussian_sa_kernel_dtype_matches_ref(shared, compute_dtype):
    """Reduced-precision fused kernel vs the scan oracle running the SAME
    simulated MXU contraction (operands rounded to the contract dtype,
    fp32 accumulation): agreement to fp32 reduction error, and the result
    stays within the mode's tolerance of the fp32 pass."""
    B, n, d, m, chunk = 3, 700, 9, 16, 256
    seeds = jnp.asarray([9, 10, 11], jnp.uint32)
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d) if shared
                          else (B, n, d))
    w = jax.random.uniform(jax.random.PRNGKey(1), (B, n),
                           minval=0.05, maxval=3.0)
    for rw in (None, w):
        got = gaussian_sa_pallas(A, seeds, m, chunk_cols=chunk,
                                 interpret=True, row_weights=rw,
                                 compute_dtype=compute_dtype)
        assert got.dtype == jnp.float32
        want = gaussian_sa_ref(A, seeds, m, row_weights=rw,
                               compute_dtype=compute_dtype)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)
        full = gaussian_sa_ref(A, seeds, m, row_weights=rw)
        rel = np.linalg.norm(np.asarray(got) - np.asarray(full)) \
            / np.linalg.norm(np.asarray(full))
        assert rel < 0.02, (compute_dtype, rel)


@pytest.mark.parametrize("compute_dtype", ["bf16", "int8"])
def test_sjlt_kernel_dtype_matches_ref(compute_dtype):
    """SJLT reduced modes: pallas vs the segment-sum oracle under the same
    rounding. int8 is EXACT vs its folded oracle — one signed nonzero per
    column means the per-row scale folds into the sign stream losslessly."""
    n, d, m = 300, 11, 32
    A = jax.random.normal(jax.random.PRNGKey(4), (n, d))
    rows = jax.random.randint(jax.random.PRNGKey(5), (n,), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(6), (n,),
                                  dtype=A.dtype)
    got = sjlt_pallas(A, rows, signs, m, interpret=True,
                      compute_dtype=compute_dtype)
    want = ref.sjlt_ref(A, rows, signs, m, compute_dtype=compute_dtype)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    full = np.asarray(ref.sjlt_ref(A, rows, signs, m))
    rel = np.linalg.norm(np.asarray(got) - full) / np.linalg.norm(full)
    assert rel < 0.02, (compute_dtype, rel)


@pytest.mark.parametrize("compute_dtype", ["bf16", "int8"])
def test_srht_sketch_dtype_modes(compute_dtype):
    """SRHT reduced modes: bf16 butterflies / int8 quantized features stay
    within the mode's tolerance of the fp32 sketch, fp32 output."""
    n, d, m = 200, 8, 64
    A = jax.random.normal(jax.random.PRNGKey(8), (n, d))
    key = jax.random.PRNGKey(10)
    got = ops.srht_sketch(A, key, m, use_pallas=True, interpret=True,
                          compute_dtype=compute_dtype)
    assert got.dtype == jnp.float32
    full = np.asarray(ops.srht_sketch(A, key, m, use_pallas=True,
                                      interpret=True))
    rel = np.linalg.norm(np.asarray(got) - full) / np.linalg.norm(full)
    assert rel < 0.03, (compute_dtype, rel)


def test_kernel_fp32_mode_bitcompat():
    """compute_dtype="fp32" lowers to the exact pre-axis graph for every
    kernel entry point — byte-identical outputs."""
    n, d, m = 256, 8, 16
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    seeds = jnp.asarray([5], jnp.uint32)
    assert bool(jnp.all(
        gaussian_sa_ref(A, seeds, m)
        == gaussian_sa_ref(A, seeds, m, compute_dtype="fp32")))
    rows = jax.random.randint(jax.random.PRNGKey(1), (n,), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(2), (n,),
                                  dtype=A.dtype)
    assert bool(jnp.all(
        ops.sjlt_apply(A, rows, signs, m)
        == ops.sjlt_apply(A, rows, signs, m, compute_dtype="fp32")))
    key = jax.random.PRNGKey(3)
    assert bool(jnp.all(
        ops.srht_sketch(A, key, m, use_pallas=True, interpret=True)
        == ops.srht_sketch(A, key, m, use_pallas=True, interpret=True,
                           compute_dtype="fp32")))


def test_srht_sketch_end_to_end():
    """kernels.ops.srht_sketch is an unbiased isometry in expectation."""
    n, d, m = 256, 16, 512
    A = jax.random.normal(jax.random.PRNGKey(5), (n, d)) / np.sqrt(n)
    G = np.asarray(A.T @ A)
    acc = np.zeros_like(G)
    reps = 24
    for r in range(reps):
        SA = ops.srht_sketch(A, jax.random.PRNGKey(r), m,
                             use_pallas=True, interpret=True)
        acc += np.asarray(SA.T @ SA)
    acc /= reps
    assert np.max(np.abs(acc - G)) < 0.15 * np.max(np.abs(G)) + 5e-3
