"""Pallas kernel validation: shape/dtype sweeps vs pure-jnp oracles,
executed in interpret mode on CPU (the TPU-target kernels' semantics).
Hypothesis property sweeps live in test_properties.py (optional dep)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.kernels.ops as ops
from repro.kernels import ref
from repro.kernels.fwht import fwht_pallas
from repro.kernels.sjlt import sjlt_pallas


@pytest.mark.parametrize("n", [8, 64, 512, 2048])
@pytest.mark.parametrize("d", [1, 7, 128, 130])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fwht_kernel_matches_ref(n, d, dtype):
    x = jax.random.normal(jax.random.PRNGKey(n * 31 + d), (n, d)).astype(dtype)
    got = fwht_pallas(x, interpret=True)
    want = ref.fwht_ref(x.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want), rtol=tol,
        atol=tol * np.sqrt(n),
    )


def test_fwht_matches_dense_hadamard():
    n, d = 128, 9
    x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    H = ref.hadamard_dense(n)
    np.testing.assert_allclose(
        np.asarray(fwht_pallas(x, interpret=True)), np.asarray(H @ x),
        rtol=1e-4, atol=1e-4,
    )


def test_fwht_large_two_pass(monkeypatch):
    monkeypatch.setattr(ops, "_FWHT_VMEM_MAX_N", 64)
    for n in [128, 1024]:
        x = jax.random.normal(jax.random.PRNGKey(n), (n, 5))
        got = ops.fwht_large(x, interpret=True)
        np.testing.assert_allclose(np.asarray(got),
                                   np.asarray(ref.fwht_ref(x)),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n,d,m,br", [
    (512, 64, 32, 256), (1000, 37, 128, 128), (256, 300, 8, 64),
    (128, 16, 2048, 128),
])
def test_sjlt_kernel_matches_ref(n, d, m, br):
    A = jax.random.normal(jax.random.PRNGKey(1), (n, d))
    rows = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(3), (n,), dtype=A.dtype)
    got = sjlt_pallas(A, rows, signs, m, interpret=True, block_rows=br)
    want = ref.sjlt_ref(A, rows, signs, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_srht_sketch_end_to_end():
    """kernels.ops.srht_sketch is an unbiased isometry in expectation."""
    n, d, m = 256, 16, 512
    A = jax.random.normal(jax.random.PRNGKey(5), (n, d)) / np.sqrt(n)
    G = np.asarray(A.T @ A)
    acc = np.zeros_like(G)
    reps = 24
    for r in range(reps):
        SA = ops.srht_sketch(A, jax.random.PRNGKey(r), m,
                             use_pallas=True, interpret=True)
        acc += np.asarray(SA.T @ SA)
    acc /= reps
    assert np.max(np.abs(acc - G)) < 0.15 * np.max(np.abs(G)) + 5e-3
