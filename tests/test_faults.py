"""Chaos suite for the failure model (DESIGN.md §9): fault classes from
``ft/faults.py`` driven through the guarded engine, the retry/fallback
driver and the serving layer. For every injected fault the suite asserts
the four failure-model invariants:

1. isolation — the faulty slot gets a non-OK status and its packed
   neighbors' solutions match a clean-batch solve to ≤ 1e-6 (lanewise
   guards make them bit-identical in most cases);
2. bounded retries — never more than ``max_retries`` redraws;
3. truthful statuses — RETRIED only after a redraw converged, FELL_BACK
   only when the answer came from ``direct_solve``, engine failures kept
   when nothing could fix the problem;
4. finite answers — every returned x is finite, always.

Pallas NaN-propagation cases run the TPU-target kernels in interpret mode
(the test_kernels.py convention). The forced-8-device shard-dropout case
uses the test_sharded.py subprocess pattern and is marked slow (CI's chaos
job runs it).
"""

import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    ENGINE_FAILURES,
    SolveStatus,
    direct_solve,
    from_least_squares_batch,
    robust_padded_solve_batched,
    status_name,
)
from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.newton import adaptive_newton_solve_batched
from repro.core.quadratic import Quadratic
from repro.ft.faults import (
    AdversarialKeyProvider,
    dropout_provider,
    ill_conditioned_matrix,
    inject_inf_entry,
    inject_nan_row,
    rank_deficient_matrix,
)
from repro.serve.solver_service import SolverService

B, N, D, M_MAX = 4, 128, 16, 32
NEIGHBOR_TOL = 1e-6
FAILURE_CODES = {int(s) for s in ENGINE_FAILURES}


@pytest.fixture(scope="module")
def clean():
    ks = jax.random.split(jax.random.PRNGKey(0), B)
    A = jnp.stack([jax.random.normal(k, (N, D)) / np.sqrt(N) for k in ks])
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, N))
    keys = jax.random.split(jax.random.PRNGKey(42), B)
    q = from_least_squares_batch(A, Y, 0.1)
    x_ref, s_ref = robust_padded_solve_batched(q, keys, m_max=M_MAX,
                                               tol=1e-10)
    return {"A": A, "Y": Y, "keys": keys, "q": q,
            "x_ref": x_ref, "s_ref": s_ref}


def _assert_invariants(x, stats, faulty, clean, *, max_retries=2):
    """The four failure-model invariants, for fault slot(s) ``faulty``."""
    status = np.asarray(stats["status"])
    neighbors = np.setdiff1d(np.arange(B), np.asarray(faulty))
    # 1. isolation
    for i in np.atleast_1d(faulty):
        assert status[i] != int(SolveStatus.OK), status_name(status[i])
    gap = np.max(np.abs(np.asarray(x)[neighbors]
                        - np.asarray(clean["x_ref"])[neighbors]))
    assert gap <= NEIGHBOR_TOL, gap
    assert np.all(status[neighbors] == int(SolveStatus.OK))
    # 2. bounded retries
    assert np.all(np.asarray(stats["retries"]) <= max_retries)
    # 3. truthful flags
    assert np.all(np.asarray(stats["fell_back"])
                  == (status == int(SolveStatus.FELL_BACK)))
    assert np.all(np.asarray(stats["converged"])
                  == np.isin(status, [int(SolveStatus.OK),
                                      int(SolveStatus.RETRIED)]))
    # 4. finite answers
    assert bool(jnp.all(jnp.isfinite(x)))


# ---------------------------------------------------------------------------
# Data faults through the robust driver
# ---------------------------------------------------------------------------

def test_nan_row_isolated(clean):
    """A NaN feature row poisons exactly its own slot; the circuit breaker
    returns its best finite iterate (x₀ here) and the direct fallback —
    equally NaN on this data — is truthfully NOT adopted."""
    A = inject_nan_row(clean["A"], problem=1, row=3)
    q = from_least_squares_batch(A, clean["Y"], 0.1)
    x, s = robust_padded_solve_batched(q, clean["keys"], m_max=M_MAX,
                                       tol=1e-10)
    _assert_invariants(x, s, [1], clean)
    status = np.asarray(s["status"])
    assert status[1] == int(SolveStatus.NAN_POISONED)
    assert not bool(np.asarray(s["fell_back"])[1])
    # poisoned data exhausts the full retry budget before giving up
    assert int(np.asarray(s["retries"])[1]) == 2


def test_inf_target_isolated(clean):
    """An Inf label behaves like the NaN row: b = Aᵀy is non-finite."""
    Y = inject_inf_entry(clean["Y"], problem=2, idx=0)
    q = from_least_squares_batch(clean["A"], Y, 0.1)
    x, s = robust_padded_solve_batched(q, clean["keys"], m_max=M_MAX,
                                       tol=1e-10)
    _assert_invariants(x, s, [2], clean)
    assert np.asarray(s["status"])[2] == int(SolveStatus.NAN_POISONED)


def test_rank_deficient_reported_not_poisoned(clean):
    """Rank-5 A with ν ≈ 0: H is numerically singular at every ladder
    level, so the verdict is LEVEL_INVALID — and since the dense oracle is
    singular too, the fallback must truthfully decline."""
    A = clean["A"].at[2].set(
        rank_deficient_matrix(jax.random.PRNGKey(9), N, D, rank=5))
    q = from_least_squares_batch(A, clean["Y"], 1e-8)
    x, s = robust_padded_solve_batched(q, clean["keys"], m_max=M_MAX,
                                       tol=1e-10)
    status = np.asarray(s["status"])
    assert status[2] == int(SolveStatus.LEVEL_INVALID)
    assert not bool(np.asarray(s["fell_back"])[2])
    assert bool(jnp.all(jnp.isfinite(x)))
    # neighbors unaffected (different ν than the clean fixture, so compare
    # against their own direct solutions rather than x_ref)
    xd = direct_solve(q)
    for i in (0, 1, 3):
        assert status[i] == int(SolveStatus.OK)
        assert float(jnp.max(jnp.abs(x[i] - xd[i]))) < 1e-3


def test_ill_conditioned_isolated(clean):
    """κ ≈ 1e10 (κ(AᵀA) ≈ 1e20, beyond f32): the slot terminates with an
    honest engine failure instead of a garbage 'converged' answer, and the
    neighbors are untouched."""
    A = clean["A"].at[2].set(
        ill_conditioned_matrix(jax.random.PRNGKey(11), N, D, 1e10))
    q = from_least_squares_batch(A, clean["Y"], 1e-4)
    x, s = robust_padded_solve_batched(q, clean["keys"], m_max=M_MAX,
                                       tol=1e-9, max_iters=40)
    status = np.asarray(s["status"])
    assert int(status[2]) in FAILURE_CODES | {int(SolveStatus.FELL_BACK)}
    assert bool(jnp.all(jnp.isfinite(x)))
    assert np.all(status[[0, 1, 3]] == int(SolveStatus.OK))
    xd = direct_solve(q)
    for i in (0, 1, 3):
        assert float(jnp.max(jnp.abs(x[i] - xd[i]))) < 1e-3


def test_stall_retry_then_fallback(clean):
    """Unreachable tolerance stalls every slot; after the bounded redraws
    the dense fallback supplies a finite answer with FELL_BACK truthfully
    set and the δ̃ certificate honestly withdrawn (NaN)."""
    x, s = robust_padded_solve_batched(clean["q"], clean["keys"],
                                       m_max=M_MAX, tol=0.0, max_iters=10,
                                       max_retries=1)
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.FELL_BACK))
    assert np.all(np.asarray(s["fell_back"]))
    assert np.all(np.asarray(s["retries"]) == 1)
    assert np.all(np.isnan(np.asarray(s["dtilde"])))
    xd = direct_solve(clean["q"])
    assert float(jnp.max(jnp.abs(x - xd))) < 1e-5
    # and without the fallback: the honest STALLED verdict + finite best
    x2, s2 = robust_padded_solve_batched(clean["q"], clean["keys"],
                                         m_max=M_MAX, tol=0.0, max_iters=10,
                                         max_retries=1, fallback=False)
    assert np.all(np.asarray(s2["status"]) == int(SolveStatus.STALLED))
    assert np.all(np.asarray(s2["stalled"]))
    assert bool(jnp.all(jnp.isfinite(x2)))


# ---------------------------------------------------------------------------
# Sketch faults
# ---------------------------------------------------------------------------

def test_adversarial_key_retry_recovers(clean):
    """A black-listed key poisons exactly its slot's sketch; the retry
    driver's fold_in redraw escapes the black-list, so the slot comes back
    RETRIED with retries=1 while the neighbors ride the first draw
    bit-identically."""
    prov = AdversarialKeyProvider("gaussian", clean["keys"][1])
    x, s = robust_padded_solve_batched(clean["q"], clean["keys"],
                                       m_max=M_MAX, tol=1e-10, sketch=prov)
    _assert_invariants(x, s, [1], clean)
    status = np.asarray(s["status"])
    assert status[1] == int(SolveStatus.RETRIED)
    assert int(np.asarray(s["retries"])[1]) == 1
    assert bool(np.asarray(s["converged"])[1])
    nb = jnp.array([0, 2, 3])
    assert bool(jnp.all(x[nb] == clean["x_ref"][nb]))  # bitwise isolation
    xd = direct_solve(clean["q"])
    assert float(jnp.max(jnp.abs(x[1] - xd[1]))) < 1e-4


def test_adversarial_key_engine_verdict(clean):
    """Without the retry driver the poisoned-draw slot terminates inside
    the engine as NAN_POISONED at its best finite iterate — the guards
    alone never emit a NaN solution."""
    prov = AdversarialKeyProvider("gaussian", clean["keys"][1])
    x, s = padded_adaptive_solve_batched(clean["q"], clean["keys"],
                                         m_max=M_MAX, tol=1e-10,
                                         sketch=prov)
    assert np.asarray(s["status"])[1] == int(SolveStatus.NAN_POISONED)
    assert bool(jnp.all(jnp.isfinite(x)))


# ---------------------------------------------------------------------------
# Infrastructure faults: simulated shard dropout
# ---------------------------------------------------------------------------

def test_shard_dropout_benign(clean):
    """Losing 1 of 4 shards of a well-spread A leaves a weaker but valid
    preconditioner (the surviving blocks still sketch the Gram): the
    engine converges with truthful OK statuses."""
    prov = dropout_provider("gaussian", 4, (1,))
    assert "drop" in prov.name
    x, s = robust_padded_solve_batched(clean["q"], clean["keys"],
                                       m_max=M_MAX, tol=1e-10, sketch=prov)
    assert np.all(np.isin(np.asarray(s["status"]),
                          [int(SolveStatus.OK), int(SolveStatus.RETRIED)]))
    xd = direct_solve(clean["q"])
    assert float(jnp.max(jnp.abs(x - xd))) < 1e-3


def test_shard_dropout_concentrated_mass_falls_back(clean):
    """When the lost shard carried the dominant row mass the surviving
    sketch misrepresents H badly enough that IHS diverges — the guards
    stall it, redraws (same survivors) cannot help, and the fallback
    returns the exact answer with FELL_BACK set."""
    scale = jnp.ones((N,)).at[32:64].set(100.0)     # all mass in shard 1/4
    A = clean["A"] * scale[None, :, None] * 0.01
    q = from_least_squares_batch(A, clean["Y"], 0.05)
    prov = dropout_provider("gaussian", 4, (1,))
    x, s = robust_padded_solve_batched(q, clean["keys"], m_max=M_MAX,
                                       tol=1e-10, method="ihs", sketch=prov,
                                       max_iters=20)
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.FELL_BACK))
    assert np.all(np.asarray(s["retries"]) <= 2)
    xd = direct_solve(q)
    assert float(jnp.max(jnp.abs(x - xd))) < 1e-5


@pytest.mark.slow
def test_shard_dropout_8shard_forced_devices():
    """The K=8 dropout story under the forced-8-device CI environment:
    2 of 8 shards lost, the re-psum'd ladder still solves benign traffic,
    and the concentrated-mass regime degrades to the fallback — never to a
    NaN or a lying OK."""
    root = Path(__file__).resolve().parents[1]
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(root / "src")}
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (SolveStatus, direct_solve,
                                from_least_squares_batch,
                                robust_padded_solve_batched)
        from repro.ft.faults import dropout_provider

        assert jax.device_count() == 8
        B, n, d = 4, 256, 16
        ks = jax.random.split(jax.random.PRNGKey(0), B)
        A = jnp.stack([jax.random.normal(k, (n, d)) / np.sqrt(n)
                       for k in ks])
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        q = from_least_squares_batch(A, Y, 0.1)
        prov = dropout_provider("gaussian", 8, (2, 5))
        x, s = robust_padded_solve_batched(q, keys, m_max=64, tol=1e-10,
                                           sketch=prov)
        ok = {int(SolveStatus.OK), int(SolveStatus.RETRIED)}
        assert all(int(c) in ok for c in np.asarray(s["status"]))
        assert float(jnp.max(jnp.abs(x - direct_solve(q)))) < 1e-3

        scale = jnp.ones((n,)).at[64:96].set(100.0)   # shard 2's rows
        q2 = from_least_squares_batch(A * scale[None, :, None] * 0.01,
                                      Y, 0.05)
        x2, s2 = robust_padded_solve_batched(q2, keys, m_max=64, tol=1e-10,
                                             method="ihs", sketch=prov,
                                             max_iters=20)
        st = np.asarray(s2["status"])
        assert np.all((st == int(SolveStatus.FELL_BACK))
                      | (st == int(SolveStatus.OK))), st
        assert np.any(st == int(SolveStatus.FELL_BACK)), st
        assert bool(jnp.all(jnp.isfinite(x2)))
        print("DROPOUT8_OK")
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=str(root), timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "DROPOUT8_OK" in r.stdout


# ---------------------------------------------------------------------------
# Engine guard regressions
# ---------------------------------------------------------------------------

def test_nu_zero_invalid_levels_skipped(clean):
    """ν = 0 makes the small ladder levels (m < d) singular — the PR 4
    failure mode. The level-validity remap now SKIPS them and converges on
    the valid tail of the ladder instead of NaN-poisoning the solve."""
    q = Quadratic(A=clean["A"], b=clean["q"].b, nu=jnp.zeros((B,)),
                  lam_diag=jnp.ones((B, D)), batched=True)
    x, s = padded_adaptive_solve_batched(q, clean["keys"], m_max=M_MAX,
                                         method="pcg", tol=1e-8)
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.OK))
    assert np.all(np.asarray(s["invalid_levels"]) > 0)
    xd = direct_solve(q)
    assert float(jnp.max(jnp.abs(x - xd))) < 1e-3


def test_whole_ladder_invalid(clean):
    """A = 0, ν = 0, b ≠ 0: no ladder level factorizes — LEVEL_INVALID
    with the x₀ = 0 iterate, not a NaN."""
    q = Quadratic(A=jnp.zeros((B, N, D)), b=jnp.ones((B, D)),
                  nu=jnp.zeros((B,)), lam_diag=jnp.ones((B, D)),
                  batched=True)
    x, s = padded_adaptive_solve_batched(q, clean["keys"], m_max=M_MAX,
                                         tol=1e-10)
    assert np.all(np.asarray(s["status"]) == int(SolveStatus.LEVEL_INVALID))
    assert bool(jnp.all(x == 0.0))


def test_guards_off_bitwise_matches_on_happy_path(clean):
    """guards=False (the benchmark escape hatch) changes NOTHING on clean
    traffic: same iterates bit-for-bit, same certificates."""
    xg, sg = padded_adaptive_solve_batched(clean["q"], clean["keys"],
                                           m_max=M_MAX, tol=1e-10,
                                           guards=True)
    xn, sn = padded_adaptive_solve_batched(clean["q"], clean["keys"],
                                           m_max=M_MAX, tol=1e-10,
                                           guards=False)
    assert bool(jnp.all(xg == xn))
    for k in ("m_final", "iters", "dtilde", "level"):
        assert np.array_equal(np.asarray(sg[k]), np.asarray(sn[k])), k


def test_glm_newton_nan_isolated():
    """The sketched-Newton GLM driver inherits the engine verdicts: a NaN
    entry poisons only its own problem and the outer status says so."""
    Bg, n, d = 3, 64, 8
    A = jax.random.normal(jax.random.PRNGKey(0), (Bg, n, d)) / np.sqrt(n)
    logits = jnp.einsum("bnd,d->bn", A, jnp.ones(d))
    y = (jax.random.uniform(jax.random.PRNGKey(1), (Bg, n))
         < jax.nn.sigmoid(logits)).astype(jnp.float32)
    A_bad = A.at[1, 0, 0].set(jnp.nan)
    x, s = adaptive_newton_solve_batched(
        "logistic", A_bad, y, 0.3, m_max=16, keys=jax.random.PRNGKey(7))
    status = np.asarray(s["status"])
    assert status[1] == int(SolveStatus.NAN_POISONED)
    assert status[0] == int(SolveStatus.OK)
    assert status[2] == int(SolveStatus.OK)
    assert bool(jnp.all(jnp.isfinite(x)))


# ---------------------------------------------------------------------------
# Pallas kernels: NaN propagation in interpret mode (satellite)
# ---------------------------------------------------------------------------

def test_pallas_gaussian_nan_weight_propagates():
    """A non-finite GLM row weight must surface as non-finite sketch
    output (→ caught by the level-validity check), never be silently
    absorbed — and only in its own problem's lane."""
    from repro.kernels import ops

    Bk, n, d, m = 3, 64, 8, 16
    A = jax.random.normal(jax.random.PRNGKey(0), (Bk, n, d))
    w = jnp.ones((Bk, n)).at[1, 5].set(jnp.nan)
    seeds = jnp.arange(Bk, dtype=jnp.uint32)
    SA = ops.gaussian_sa(A, seeds, m, use_pallas=True, interpret=True,
                         row_weights=w)
    assert not bool(jnp.all(jnp.isfinite(SA[1])))
    assert bool(jnp.all(jnp.isfinite(SA[0])))
    assert bool(jnp.all(jnp.isfinite(SA[2])))


def test_pallas_sjlt_nan_entry_propagates():
    """A NaN data entry reaches the SJLT kernel output for its problem
    only (one signed nonzero per column keeps lanes independent)."""
    from repro.kernels import ops

    Bk, n, d, m = 3, 64, 8, 16
    A = jax.random.normal(jax.random.PRNGKey(2), (Bk, n, d))
    A = A.at[2, 7, 3].set(jnp.nan)
    rows = jax.random.randint(jax.random.PRNGKey(3), (Bk, n), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(4), (Bk, n),
                                  dtype=A.dtype)
    SA = ops.sjlt_apply_batched(A, rows, signs, m, use_pallas=True,
                                interpret=True)
    assert not bool(jnp.all(jnp.isfinite(SA[2])))
    assert bool(jnp.all(jnp.isfinite(SA[0])))
    assert bool(jnp.all(jnp.isfinite(SA[1])))


def test_pallas_fwht_nan_scale_propagates():
    """A NaN SRHT row scale (sign·w^{1/2} stream) must propagate through
    the FWHT butterfly for its own problem only."""
    from repro.kernels import ops

    Bk, n, d = 3, 64, 8
    X = jax.random.normal(jax.random.PRNGKey(5), (Bk, n, d))
    scale = jnp.ones((Bk, n)).at[0, 11].set(jnp.nan)
    HX = ops.fwht_cols(X, use_pallas=True, interpret=True, row_scale=scale)
    assert not bool(jnp.all(jnp.isfinite(HX[0])))
    assert bool(jnp.all(jnp.isfinite(HX[1])))
    assert bool(jnp.all(jnp.isfinite(HX[2])))


@pytest.mark.parametrize("sketch", ["gaussian", "sjlt", "srht"])
def test_nan_weight_caught_by_level_validity(clean, sketch):
    """End-to-end across all three ladder families: a non-finite row
    weight in a weighted (GLM-style) solve is caught by the post-Cholesky
    level-validity check and reported NAN_POISONED for that slot only."""
    w = jnp.ones((B, N)).at[1, 0].set(jnp.nan)
    q = Quadratic(A=clean["A"], b=clean["q"].b, nu=clean["q"].nu,
                  lam_diag=clean["q"].lam_diag, batched=True, row_weights=w)
    x, s = padded_adaptive_solve_batched(q, clean["keys"], m_max=M_MAX,
                                         method="pcg", tol=1e-8,
                                         sketch=sketch)
    status = np.asarray(s["status"])
    assert status[1] == int(SolveStatus.NAN_POISONED)
    assert np.all(status[[0, 2, 3]] == int(SolveStatus.OK))
    assert bool(jnp.all(jnp.isfinite(x)))


# ---------------------------------------------------------------------------
# Serving layer
# ---------------------------------------------------------------------------

def _good_request(i, n=100, d=12):
    A = jax.random.normal(jax.random.PRNGKey(3 * i), (n, d)) / np.sqrt(n)
    y = jax.random.normal(jax.random.PRNGKey(3 * i + 1), (n,))
    return A, y, 0.3


def test_service_strict_submit_validation():
    """strict mode rejects non-finite A / y / Λ and ν ≤ 0 at submit,
    naming the request — on every entry point including solve_one."""
    svc = SolverService(batch_size=4)
    A, y, nu = _good_request(0)
    with pytest.raises(ValueError, match="request 0.*non-finite entries in A"):
        svc.submit(A.at[0, 0].set(jnp.nan), y, nu)
    with pytest.raises(ValueError, match="non-finite entries in y"):
        svc.submit(A, y.at[3].set(jnp.inf), nu)
    with pytest.raises(ValueError, match="non-finite entries in lam_diag"):
        svc.submit(A, y, nu, lam_diag=jnp.full((A.shape[1],), jnp.nan))
    with pytest.raises(ValueError, match="nu must be"):
        svc.submit(A, y, 0.0)
    with pytest.raises(ValueError, match="nu must be"):
        svc.solve_one(A, y, float("inf"))
    with pytest.raises(ValueError, match="non-finite entries in A"):
        svc.submit_glm(A.at[0, 0].set(jnp.nan), (y > 0).astype(jnp.float32),
                       nu, family="logistic")
    with pytest.raises(ValueError, match="expected"):
        svc.submit(A, y[:-1], nu)      # malformed shape always raises


def test_service_quarantine_isolates_bad_requests():
    """strict=False: invalid requests are quarantined into REJECTED
    solutions and their packed would-be neighbors solve exactly as in a
    clean service (same req-id keys → same answers)."""
    svc_clean = SolverService(batch_size=4, seed=7)
    svc = SolverService(batch_size=4, seed=7, strict=False)
    good = []
    for i in range(3):
        A, y, nu = _good_request(i)
        svc_clean.submit(A, y, nu)
        good.append(svc.submit(A, y, nu))
    bad = svc.submit(jnp.full((64, 8), jnp.nan), jnp.zeros(64), 0.1)
    bad_nu = svc.submit(*_good_request(9)[:2], 0.0)
    ref = svc_clean.flush()
    sols = svc.flush()
    assert sols[bad].status == "REJECTED"
    assert sols[bad_nu].status == "REJECTED"
    assert not sols[bad].converged
    assert "non-finite entries in A" in svc.rejection_reasons[bad]
    assert svc.stats["rejected"] == 2
    for rid in good:
        assert sols[rid].status == "OK"
        assert float(jnp.max(jnp.abs(sols[rid].x - ref[rid].x))) <= 1e-6


def test_service_stalled_flag_regression():
    """Satellite regression: a stalled-at-cap request is DISTINGUISHABLE
    in its certificate — status/stalled/converged say so explicitly
    instead of being folded into 'done'."""
    svc = SolverService(batch_size=4, tol=0.0, max_iters=5,
                        max_retries=0, fallback=False)
    A, y, nu = _good_request(1)
    sol = svc.solve_one(A, y, nu)
    assert sol.status == "STALLED"
    assert sol.stalled and not sol.converged and not sol.fell_back
    assert bool(jnp.all(jnp.isfinite(sol.x)))
    # and the fallback path flags itself truthfully too
    svc2 = SolverService(batch_size=4, tol=0.0, max_iters=5,
                         max_retries=1, fallback=True)
    sol2 = svc2.solve_one(A, y, nu)
    assert sol2.status == "FELL_BACK"
    assert sol2.fell_back and sol2.retries == 1
    assert np.isnan(sol2.delta_tilde)
    assert svc2.stats["fallbacks"] == 1


def test_service_flush_deadline_partial_results():
    """A spent flush budget returns the undispatched remainder immediately
    as DEADLINE_EXCEEDED instead of blocking — partial results, truthful
    statuses, nothing lost silently."""
    svc = SolverService(batch_size=2)
    rids = [svc.submit(*_good_request(i)) for i in range(4)]
    sols = svc.flush(deadline_s=0.0)
    assert len(sols) == 4
    for rid in rids:
        assert sols[rid].status == "DEADLINE_EXCEEDED"
        assert not sols[rid].converged
    assert svc.stats["deadline_exceeded"] == 4
    # resubmission after the deadline flush works normally
    rid = svc.submit(*_good_request(0))
    assert svc.flush()[rid].status == "OK"


def test_service_glm_status_surface():
    """GLM certificates carry the same status surface (OK on clean
    traffic; the stalled flag wired through the Newton driver)."""
    svc = SolverService(batch_size=2)
    A, y, _ = _good_request(5, n=80, d=10)
    rid = svc.submit_glm(A, (y > 0).astype(jnp.float32), 0.3,
                         family="logistic")
    sol = svc.flush()[rid]
    assert sol.status == "OK"
    assert sol.converged and not sol.stalled
    assert sol.retries == 0 and not sol.fell_back
