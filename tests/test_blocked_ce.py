"""Blocked cross-entropy (§Perf B4): exact equivalence with the reference
loss, including z-loss, softcap, masking, and gradients."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import init_params
from repro.train.step import blocked_lm_loss, lm_loss


@pytest.mark.parametrize("arch,chunks", [
    ("qwen2-0.5b", 8),          # tied embeddings
    ("gemma2-27b", 4),          # final softcap + embed scale
])
def test_blocked_ce_matches_reference(arch, chunks):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    mask = jnp.ones((B, S)).at[0, :3].set(0.0)  # partial mask
    args = (toks[:, :-1], toks[:, 1:], mask)

    f_ref = lambda p: lm_loss(p, cfg, *args, compute_dtype=jnp.float32)[0]
    f_blk = lambda p: blocked_lm_loss(
        p, cfg, *args, ce_chunks=chunks, compute_dtype=jnp.float32)[0]
    l1, g1 = jax.value_and_grad(f_ref)(params)
    l2, g2 = jax.value_and_grad(f_blk)(params)
    assert abs(float(l1 - l2)) < 1e-5
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


def test_blocked_ce_train_step_converges():
    from repro.train import AdamWConfig, TrainConfig, init_opt_state
    from repro.train.step import make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=32)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-2, warmup_steps=2,
                                       total_steps=40),
                       num_microbatches=2, compute_dtype=jnp.float32,
                       ce_chunks=8)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, cfg.vocab)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:],
             "mask": jnp.ones((4, 16), jnp.float32)}
    losses = []
    for _ in range(25):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.6
