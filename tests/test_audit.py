"""The invariant auditor audits itself: every rule must FAIL on its
seeded-violation fixture (with provenance pointing into the fixture) and
pass on the real stack — a rule that cannot catch its own negative
control is a rubber stamp, not a gate."""

import jax.numpy as jnp

from repro.analysis.audit import RULES, fixtures as fx
from repro.analysis.audit.ast_rules import lint_module_source
from repro.analysis.audit.hlo_utils import (
    collective_bytes_from_hlo,
    donated_input_indices,
)
from repro.analysis.audit.runner import run_audit

RULE = {r.name: r for r in RULES}


def _check(rule_name, ep):
    rule = RULE[rule_name]
    assert rule.applies(ep), (rule_name, ep.name)
    return rule.check(ep, ep.build())


# ---------------------------------------------------------------------------
# negative controls: each rule catches its seeded violation
# ---------------------------------------------------------------------------

def test_one_touch_catches_dense_sketch():
    vs = _check("one_touch", fx.dense_sketch_ep())
    msgs = " ".join(v.message for v in vs)
    assert "dense sketch materialized" in msgs
    assert "exceeds the live-set budget" in msgs       # peak rule fires too
    assert any("fixtures.py" in v.provenance for v in vs)


def test_one_touch_catches_fp32_a_copy():
    vs = _check("one_touch", fx.a_copy_ep())
    assert len(vs) == 1
    assert "(B, n, d) copy of A" in vs[0].message
    assert "fixtures.py" in vs[0].provenance


def test_collective_inventory_catches_double_psum():
    vs = _check("collective_inventory", fx.double_psum_ep())
    assert any("2 psums" in v.message for v in vs)


def test_collective_inventory_catches_loop_collective():
    vs = _check("collective_inventory", fx.loop_collective_ep())
    assert any("inside the adaptive while_loop body" in v.message
               for v in vs)
    assert any("fixtures.py" in v.provenance for v in vs)


def test_precision_boundary_catches_bf16_pipeline():
    vs = _check("precision_boundary", fx.bf16_cholesky_ep())
    msgs = " ".join(v.message for v in vs)
    assert "cholesky" in msgs                          # bf16 factorization
    assert "while_loop carries a bfloat16" in msgs     # bf16 loop state
    assert "accumulates into bfloat16" in msgs         # bf16 contraction


def test_key_hygiene_catches_reused_literals():
    vs = lint_module_source(fx.REUSED_ROOT_KEY_SRC, "fx.roots", "fx.py")
    assert len(vs) == 1 and "PRNGKey(42) constructed twice" in vs[0].message
    vs = lint_module_source(fx.REUSED_FOLD_IN_SRC, "fx.folds", "fx.py")
    assert len(vs) == 1 and "fold_in" in vs[0].message
    assert vs[0].provenance.startswith("fx.py:")


def test_status_lattice_catches_bare_literal_compare():
    vs = lint_module_source(fx.BARE_STATUS_SRC, "fx.status", "fx.py")
    assert len(vs) == 1 and vs[0].rule == "status_lattice"
    assert not lint_module_source(fx.CLEAN_STATUS_SRC, "fx.ok", "fx.py")


def test_retrace_sentinel_catches_leaky_static():
    """A per-request value routed through a static argument recompiles on
    every fresh request — the cache-size delta the sentinel keys on."""
    leaky = fx.make_leaky_static_fn()
    x = jnp.ones((4,))
    leaky(x, nu=0.1)
    before = leaky._cache_size()
    leaky(x, nu=0.2)                  # same shapes, fresh VALUE
    assert leaky._cache_size() == before + 1


def test_donation_audit_catches_undonated_state():
    undonated = fx.make_undonated_segment_fn()
    st = {"x": jnp.ones((3,)), "r": jnp.zeros((3,))}
    text = undonated.lower(jnp.float32(1.0), st).as_text()
    assert donated_input_indices(text) == set()


# ---------------------------------------------------------------------------
# positive controls: the real stack passes, end to end through the runner
# ---------------------------------------------------------------------------

def test_runner_quick_jaxpr_rules_pass():
    """The CI-quick provider surface is clean under every jaxpr rule (the
    full matrix runs in the CI audit job; this keeps tier-1 honest)."""
    report = run_audit(quick=True, run_exec=False,
                       entry_filter="provider:gaussian")
    assert report.results, "no entry points matched"
    assert report.passed, report.human_report()


def test_runner_source_lints_pass_on_src():
    report = run_audit(quick=True, run_exec=False, rule_filter="hygiene")
    assert any(r.rule == "key_hygiene" for r in report.results)
    assert report.passed, report.human_report()


def test_real_segment_state_is_fully_donated():
    """The production segment executable donates all 20 PaddedState leaves
    (the fix the auditor forced): re-dispatch reuses the state buffers."""
    from repro.analysis.audit.retrace import check_state_donation

    assert check_state_donation() == []


def test_report_summary_shape():
    """benchmarks/run.py embeds summary(); pin its schema."""
    report = run_audit(quick=True, run_exec=False,
                       entry_filter="provider:gaussian:fp32:unweighted")
    s = report.summary()
    assert set(s) == {"passed", "checks", "failed", "quick", "by_rule"}
    assert s["checks"] == sum(c["checked"] for c in s["by_rule"].values())
    d = report.as_dict()
    assert {r["rule"] for r in d["results"]} == set(s["by_rule"])


def test_collective_bytes_parser_on_synthetic_hlo():
    hlo = """
  %ar = f32[9,3,16,16]{3,2,1,0} all-reduce(f32[9,3,16,16]{3,2,1,0} %x)
  %ag = bf16[4,8]{1,0} all-gather-start(bf16[4,8]{1,0} %y)
  %agd = bf16[4,8]{1,0} all-gather-done(bf16[4,8]{1,0} %ag)
"""
    got = collective_bytes_from_hlo(hlo)
    assert got["by_op"]["all-reduce"]["bytes"] == 9 * 3 * 16 * 16 * 4
    assert got["by_op"]["all-gather"]["count"] == 1
    assert got["total_bytes"] == 9 * 3 * 16 * 16 * 4 + 4 * 8 * 2


def test_fixture_registry_all_fail():
    """Every registered fixture is caught by at least one rule — nothing
    in the negative-control set silently goes green."""
    for mk in fx.ALL_FIXTURES:
        ep = mk()
        closed = ep.build()
        total = sum(len(r.check(ep, closed)) for r in RULES
                    if r.applies(ep))
        assert total > 0, ep.name
