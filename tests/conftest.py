"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real device count (1); distributed tests spawn subprocesses with
their own flags (tests/test_dist.py)."""

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def ridge_problem():
    """Small ill-conditioned ridge problem with known direct solution."""
    from repro.core import from_least_squares, direct_solve, effective_dimension
    from repro.core.effective_dim import exp_decay_singular_values

    n, d, rate, nu = 2048, 256, 0.9, 1e-2
    key = jax.random.PRNGKey(0)
    sv = exp_decay_singular_values(d, rate)
    kU, kV, ky = jax.random.split(key, 3)
    U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
    V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
    A = (U * sv[None, :]) @ V.T
    y = jax.random.normal(ky, (n,))
    q = from_least_squares(A, y, nu)
    return {
        "q": q,
        "x_star": direct_solve(q),
        "d_e": float(effective_dimension(sv, nu)),
        "sv": sv,
    }
