"""Chaos suite for preemptible solves (DESIGN.md §11): the segmented
engine + host driver under deadlines, SIGTERM preemption, kill -9 crashes
and mid-solve shard loss. The invariants:

1. fidelity — a segmented solve is BITWISE the monolithic one (same
   compiled while_loop body under a traced trip limit, full ``PaddedState``
   round-trip), for every method and segment size;
2. honest deadlines — a spent budget stops dispatching and returns the
   best finite iterate with its real δ̃ and ``DEADLINE_EXCEEDED``; expired
   slots are never retried or fallen back (more time is exactly what the
   deadline forbids);
3. durable progress — SIGTERM checkpoints through
   ``ft.checkpoint.CheckpointManager`` and a restarted process resumes
   from the last committed segment with numerics matching an uninterrupted
   run (bitwise when segment boundaries align, which ``checkpoint_every=1``
   guarantees);
4. elastic recovery — losing a data shard mid-solve recombines the
   surviving cached level Grams (one subtraction, no re-touch of surviving
   rows), repreconditions, and still finishes ``OK`` with a truthful
   certificate — the true Hessian (``gram_hvp`` serving default) never
   referenced the lost shard.

The kill -9 and forced-8-device cases use the test_sharded.py subprocess
pattern and are marked slow (CI's chaos job runs them).
"""

import os
import signal
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    PreemptedError,
    SolveStatus,
    direct_solve,
    from_least_squares_batch,
    robust_padded_solve_batched,
    segmented_padded_solve_batched,
)
from repro.core.adaptive_padded import (
    doubling_ladder,
    padded_adaptive_solve_batched,
)
from repro.core.distributed import ShardLadderCache
from repro.core.level_grams import BlockEmulationProvider
from repro.ft import CheckpointManager, PreemptionHandler
from repro.ft.faults import ShardLossInjector
from repro.serve.solver_service import SolverService

B, N, D, M_MAX = 4, 128, 16, 32


@pytest.fixture(scope="module")
def clean():
    ks = jax.random.split(jax.random.PRNGKey(0), B)
    A = jnp.stack([jax.random.normal(k, (N, D)) / np.sqrt(N) for k in ks])
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, N))
    keys = jax.random.split(jax.random.PRNGKey(42), B)
    q = from_least_squares_batch(A, Y, 0.1)
    x_ref, s_ref = padded_adaptive_solve_batched(q, keys, m_max=M_MAX,
                                                 method="pcg", tol=1e-10)
    return {"q": q, "keys": keys, "x_ref": x_ref, "s_ref": s_ref}


def _assert_bitwise(x, s, x_ref, s_ref):
    assert bool(jnp.all(x == x_ref))
    for k in ("status", "m_final", "iters", "dtilde", "level", "doublings"):
        np.testing.assert_array_equal(np.asarray(s[k]), np.asarray(s_ref[k]),
                                      err_msg=k)


# ---------------------------------------------------------------------------
# Fidelity: segmented == monolithic, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", ["ihs", "pcg", "polyak"])
@pytest.mark.parametrize("segment_trips", [5, 32])
def test_segmented_bitwise_matches_monolithic(clean, method, segment_trips):
    """Chopping the while_loop into k-trip dispatches changes NOTHING: the
    state that crosses each boundary is the loop carry itself."""
    x_ref, s_ref = padded_adaptive_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, method=method, tol=1e-10)
    x, s = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, method=method, tol=1e-10,
        segment_trips=segment_trips)
    _assert_bitwise(x, s, x_ref, s_ref)
    assert s["segments"] >= 1 and not s["resumed"] and not s["deadline_hit"]


def test_segmented_guards_off_bitwise(clean):
    """The benchmark escape hatch segments identically."""
    x_ref, s_ref = padded_adaptive_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, method="pcg", tol=1e-10,
        guards=False)
    x, s = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, method="pcg", tol=1e-10,
        guards=False, segment_trips=7)
    _assert_bitwise(x, s, x_ref, s_ref)


# ---------------------------------------------------------------------------
# Honest deadlines
# ---------------------------------------------------------------------------

def test_mid_solve_deadline_honest(clean):
    """deadline_s=0.0 admits exactly ONE segment (the first always runs):
    unfinished problems come back DEADLINE_EXCEEDED at their best finite
    iterate with a REAL δ̃ — partial progress, truthfully labelled."""
    x, s = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=0.0, segment_trips=8,
        deadline_s=0.0)
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.DEADLINE_EXCEEDED))
    assert s["deadline_hit"] and s["segments"] == 1
    assert bool(jnp.all(jnp.isfinite(x)))
    dt = np.asarray(s["dtilde"])
    assert np.all(np.isfinite(dt)) and np.all(dt > 0)
    assert np.all(np.asarray(s["iters"]) > 0)


def test_deadline_slots_never_retried_or_fallen_back(clean):
    """DEADLINE_EXCEEDED is not an engine failure: the retry/fallback
    driver must not spend MORE wall-clock on a slot whose budget is the
    thing that ran out."""
    x, s = robust_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=0.0, segment_trips=8,
        deadline_s=0.0, max_retries=2, fallback=True)
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.DEADLINE_EXCEEDED))
    assert np.all(np.asarray(s["retries"]) == 0)
    assert not np.any(np.asarray(s["fell_back"]))
    assert np.all(np.isfinite(np.asarray(s["dtilde"])))
    assert bool(jnp.all(jnp.isfinite(x)))
    assert s["deadline_hit"]


def test_generous_deadline_is_bitwise_noop(clean):
    """A deadline that never binds changes nothing — same bits as the
    monolithic solve."""
    x, s = robust_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, deadline_s=3600.0)
    _assert_bitwise(x, s, clean["x_ref"], clean["s_ref"])
    assert not s["deadline_hit"]


# ---------------------------------------------------------------------------
# Preemption + checkpoint/resume
# ---------------------------------------------------------------------------

class _Preempt:
    should_stop = False


def test_preempt_checkpoint_resume_bitwise(clean, tmp_path):
    """Preempted at segment 2 → state checkpointed → PreemptedError; a
    second invocation resumes from the committed segment and finishes
    bitwise identical to an uninterrupted segmented run."""
    x_ref, s_ref = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4)
    assert s_ref["segments"] >= 3  # the preemption below lands mid-solve

    ckpt = CheckpointManager(tmp_path / "ck")
    pre = _Preempt()

    def trip_wire(seg, st):
        if seg == 2:
            pre.should_stop = True
        return None

    with pytest.raises(PreemptedError) as ei:
        segmented_padded_solve_batched(
            clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10,
            segment_trips=4, checkpoint=ckpt, checkpoint_every=1,
            preempt=pre, on_segment=trip_wire)
    assert ei.value.segment == 2
    assert ckpt.latest_step() == 2

    x, s = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4,
        checkpoint=ckpt, resume=True)
    assert s["resumed"]
    assert s["segments"] == s_ref["segments"] - 2
    _assert_bitwise(x, s, x_ref, s_ref)

    # resuming an already-finished solve restores, dispatches nothing, and
    # reproduces the answer
    x2, s2 = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4,
        checkpoint=ckpt, resume=True)
    assert s2["resumed"] and s2["segments"] == 0
    _assert_bitwise(x2, s2, x_ref, s_ref)


def test_sigterm_checkpoints_and_resumes(clean, tmp_path):
    """The real signal path: ft.PreemptionHandler catches SIGTERM mid-solve,
    the driver commits a checkpoint and raises; the 'restarted' solve
    resumes bitwise."""
    x_ref, _ = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4)

    def self_sigterm(seg, st):
        if seg == 2:
            os.kill(os.getpid(), signal.SIGTERM)
            time.sleep(0.05)  # let the python-level handler run
        return None

    with PreemptionHandler(signals=(signal.SIGTERM,)) as handler:
        with pytest.raises(PreemptedError):
            segmented_padded_solve_batched(
                clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10,
                segment_trips=4, checkpoint=str(tmp_path / "ck"),
                preempt=handler, on_segment=self_sigterm)

    x, s = segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4,
        checkpoint=str(tmp_path / "ck"), resume=True)
    assert s["resumed"]
    assert bool(jnp.all(x == x_ref))


def test_resume_fingerprint_mismatch_raises(clean, tmp_path):
    """A checkpoint from a DIFFERENT solve (here: another m_max) must be
    rejected loudly, not silently resumed onto the wrong problem."""
    segmented_padded_solve_batched(
        clean["q"], clean["keys"], m_max=M_MAX, tol=1e-10, segment_trips=4,
        checkpoint=str(tmp_path / "ck"))
    with pytest.raises(ValueError, match="fingerprint mismatch"):
        segmented_padded_solve_batched(
            clean["q"], clean["keys"], m_max=16, tol=1e-10, segment_trips=4,
            checkpoint=str(tmp_path / "ck"), resume=True)


# ---------------------------------------------------------------------------
# Elastic mid-solve shard recovery
# ---------------------------------------------------------------------------

def test_shard_cache_total_matches_provider(clean):
    """The cached per-shard contributions sum (in shard order) to exactly
    the BlockEmulationProvider's Grams — same fold_in(key, k) randomness,
    same accumulation order, bitwise."""
    ladder = doubling_ladder(M_MAX)
    q, keys = clean["q"], clean["keys"]
    prov = BlockEmulationProvider("gaussian", 4)
    data = prov.sample(keys, M_MAX, q.n, q.A.dtype)
    g_ref = prov.level_grams(data, q, ladder)
    cache = ShardLadderCache.from_emulation("gaussian", keys, q, ladder, 4)
    np.testing.assert_array_equal(np.asarray(cache.total()),
                                  np.asarray(g_ref))
    # dropping shard 1 ≈ the provider that never saw shard 1 (one
    # subtraction vs a fresh 3-shard sum: same value, different rounding)
    dropped = cache.drop(1)
    prov_drop = BlockEmulationProvider("gaussian", 4, drop_shards=(1,))
    g_drop = prov_drop.level_grams(prov_drop.sample(keys, M_MAX, q.n,
                                                   q.A.dtype), q, ladder)
    np.testing.assert_allclose(np.asarray(dropped), np.asarray(g_drop),
                               atol=1e-5)
    assert cache.alive == {0, 2, 3}
    with pytest.raises(ValueError):
        cache.drop(1)  # already dead


def test_shard_loss_mid_solve_recovers_ok(clean):
    """A shard dies at segment 2: the injector recombines the surviving
    level Grams (cache.drop — no surviving row re-touched), the driver
    repreconditions, and the solve finishes OK with a certificate the
    K−1-shard preconditioner honestly earned. gram_hvp=True is the serving
    default that makes this sound: the TRUE Hessian never referenced the
    lost shard."""
    ladder = doubling_ladder(M_MAX)
    q, keys = clean["q"], clean["keys"]
    cache = ShardLadderCache.from_emulation("gaussian", keys, q, ladder, 4)
    inj = ShardLossInjector(cache, shard=1, at_segment=2)
    x, s = segmented_padded_solve_batched(
        q, keys, m_max=M_MAX, method="pcg", tol=1e-10, segment_trips=4,
        gram_hvp=True, grams=cache.total(), on_segment=inj)
    assert inj.fired and inj.fired_at == 2
    assert cache.alive == {0, 2, 3}
    status = np.asarray(s["status"])
    assert np.all(status == int(SolveStatus.OK)), status
    assert np.all(np.isfinite(np.asarray(s["dtilde"])))
    xd = direct_solve(q)
    assert float(jnp.max(jnp.abs(x - xd))) < 1e-4


# ---------------------------------------------------------------------------
# Serving layer: per-request deadlines + EDF
# ---------------------------------------------------------------------------

def _req(i, n=100, d=16):
    A = jax.random.normal(jax.random.PRNGKey(5 * i), (n, d)) / np.sqrt(n)
    y = jax.random.normal(jax.random.PRNGKey(5 * i + 1), (n,))
    return A, y, 0.3


def test_service_edf_dispatch_order():
    """flush() dispatches earliest-deadline chunks first; deadline-less
    traffic goes last in submit order."""
    svc = SolverService(batch_size=1)
    r_late = svc.submit(*_req(0), deadline_s=100.0)
    r_none = svc.submit(*_req(1))
    r_soon = svc.submit(*_req(2), deadline_s=50.0)
    order = []
    orig = svc._solve_chunk

    def spy(cls, reqs, budget_s=None):
        order.extend(r.req_id for r in reqs)
        return orig(cls, reqs, budget_s=budget_s)

    svc._solve_chunk = spy
    sols = svc.flush()
    assert order == [r_soon, r_late, r_none]
    assert all(sols[r].status == "OK" for r in (r_late, r_none, r_soon))


def test_service_request_deadline_spent_before_dispatch():
    """A request whose deadline is already past when its chunk comes up is
    expired WITHOUT dispatching: x = 0, NaN certificate, truthful status —
    the undispatched flavor of DEADLINE_EXCEEDED."""
    svc = SolverService(batch_size=4)
    rid = svc.submit(*_req(3), deadline_s=0.0)
    sol = svc.flush()[rid]
    assert sol.status == "DEADLINE_EXCEEDED"
    assert sol.iters == 0 and np.isnan(sol.delta_tilde)
    assert not sol.converged
    assert bool(jnp.all(sol.x == 0.0))
    assert svc.stats["deadline_exceeded"] == 1
    # the service stays usable afterwards
    rid2 = svc.submit(*_req(3))
    assert svc.flush()[rid2].status == "OK"


def test_service_request_deadline_binds_mid_solve():
    """A budget that is positive at dispatch but shorter than the solve is
    enforced BETWEEN segments: the request comes back DEADLINE_EXCEEDED
    with real partial progress (iters > 0, finite δ̃) — the dispatched
    flavor. tol=0 makes convergence impossible, so only the deadline can
    end it."""
    svc = SolverService(batch_size=4, tol=0.0, max_iters=3000,
                        max_retries=0, fallback=False, segment_trips=8)
    rid = svc.submit(*_req(4, n=112, d=20), 0.1, deadline_s=0.05)
    sol = svc.flush()[rid]
    assert sol.status == "DEADLINE_EXCEEDED"
    assert sol.iters > 0 and np.isfinite(sol.delta_tilde)
    assert bool(jnp.all(jnp.isfinite(sol.x)))
    assert svc.stats["deadline_exceeded"] == 1
    assert svc.stats["segments"] >= 1


def test_service_glm_deadline_between_newton_steps():
    """GLM requests honor deadline_s= too: the Newton driver checks the
    budget between OUTER steps (the first always runs) and reports the
    honest decrement at the step it stopped on."""
    svc = SolverService(batch_size=4, max_retries=0, fallback=False)
    svc.newton_tol = 0.0
    svc.newton_iters = 500
    A, y, _ = _req(6, n=144, d=20)
    rid = svc.submit_glm(A, (y > 0).astype(jnp.float32), 0.5,
                         family="logistic", deadline_s=0.05)
    sol = svc.flush()[rid]
    assert sol.status == "DEADLINE_EXCEEDED"
    assert sol.newton_iters > 0 and np.isfinite(sol.decrement)
    assert bool(jnp.all(jnp.isfinite(sol.x)))
    assert svc.stats["deadline_exceeded"] == 1


# ---------------------------------------------------------------------------
# Subprocess chaos: kill -9 + restart, forced-8-device shard loss
# ---------------------------------------------------------------------------

_CHILD_SOLVE = textwrap.dedent("""
    import hashlib, sys
    import jax, jax.numpy as jnp, numpy as np
    from repro.core import from_least_squares_batch
    from repro.core.robust import segmented_padded_solve_batched

    ckpt = sys.argv[1] if len(sys.argv) > 1 else None
    B, n, d = 4, 128, 16
    ks = jax.random.split(jax.random.PRNGKey(0), B)
    A = jnp.stack([jax.random.normal(k, (n, d)) / np.sqrt(n) for k in ks])
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
    keys = jax.random.split(jax.random.PRNGKey(42), B)
    q = from_least_squares_batch(A, Y, 0.1)

    def mark(seg, st):
        print(f"SEG {seg}", flush=True)
        return None

    x, s = segmented_padded_solve_batched(
        q, keys, m_max=32, method="pcg", tol=1e-10, segment_trips=2,
        checkpoint=ckpt, checkpoint_every=1, on_segment=mark)
    xb = np.ascontiguousarray(np.asarray(x, np.float32)).tobytes()
    print("RESUMED", int(s["resumed"]), flush=True)
    print("SEGMENTS", int(s["segments"]), flush=True)
    print("STATUS", ",".join(str(int(v)) for v in np.asarray(s["status"])),
          flush=True)
    print("MFINAL", ",".join(str(int(v)) for v in np.asarray(s["m_final"])),
          flush=True)
    print("XHASH", hashlib.sha1(xb).hexdigest(), flush=True)
""")


def _marks(stdout: str) -> dict:
    out = {}
    for line in stdout.splitlines():
        parts = line.split(None, 1)
        if parts and parts[0] in ("RESUMED", "SEGMENTS", "STATUS", "MFINAL",
                                  "XHASH"):
            out[parts[0]] = parts[1] if len(parts) > 1 else ""
    return out


@pytest.mark.slow
def test_kill9_restart_resumes_bitwise(tmp_path):
    """The crash story end to end: kill -9 (no signal handler gets a say)
    a solve mid-flight, restart the process, and the resumed run converges
    with IDENTICAL m_final and bitwise-identical x vs an uninterrupted run
    — checkpoint_every=1 aligns every segment boundary."""
    root = Path(__file__).resolve().parents[1]
    env = {**os.environ, "PYTHONPATH": str(root / "src")}
    ck = str(tmp_path / "ck")

    # run 1: kill -9 as soon as segment 3 is reported
    p = subprocess.Popen([sys.executable, "-u", "-c", _CHILD_SOLVE, ck],
                         stdout=subprocess.PIPE, stderr=subprocess.PIPE,
                         text=True, env=env, cwd=str(root))
    killed = False
    deadline = time.time() + 600
    for line in p.stdout:
        if line.startswith("SEG 3"):
            p.kill()                      # SIGKILL: nothing gets to clean up
            killed = True
            break
        if time.time() > deadline:
            p.kill()
            pytest.fail("child never reached segment 3")
    p.wait(timeout=60)

    # run 2: restart, resume from the last COMMITTED segment, finish
    r2 = subprocess.run([sys.executable, "-u", "-c", _CHILD_SOLVE, ck],
                        capture_output=True, text=True, env=env,
                        cwd=str(root), timeout=600)
    assert r2.returncode == 0, f"stderr:\n{r2.stderr[-3000:]}"
    m2 = _marks(r2.stdout)

    # run 3: uninterrupted reference (fresh checkpoint dir)
    r3 = subprocess.run([sys.executable, "-u", "-c", _CHILD_SOLVE,
                         str(tmp_path / "ref")],
                        capture_output=True, text=True, env=env,
                        cwd=str(root), timeout=600)
    assert r3.returncode == 0, f"stderr:\n{r3.stderr[-3000:]}"
    m3 = _marks(r3.stdout)

    if killed:
        assert m2["RESUMED"] == "1"
        assert int(m2["SEGMENTS"]) < int(m3["SEGMENTS"])
    assert m2["STATUS"] == m3["STATUS"] == ",".join(
        [str(int(SolveStatus.OK))] * 4)
    assert m2["MFINAL"] == m3["MFINAL"]
    assert m2["XHASH"] == m3["XHASH"]   # aligned boundaries ⇒ bitwise


@pytest.mark.slow
def test_shard_loss_8devices_forced():
    """The elastic story under the forced-8-device CI environment: the
    per-shard ladder Grams are cached from the REAL sharded pass, device 5
    'dies' at segment 2, and the re-meshed 7-shard solve finishes OK
    without re-reading any surviving shard's rows."""
    root = Path(__file__).resolve().parents[1]
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(root / "src")}
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import (SolveStatus, direct_solve,
                                from_least_squares_batch)
        from repro.core.adaptive_padded import doubling_ladder
        from repro.core.distributed import ShardLadderCache
        from repro.core.robust import segmented_padded_solve_batched
        from repro.ft.faults import ShardLossInjector

        assert jax.device_count() == 8
        B, n, d, m_max = 4, 256, 16, 64
        ks = jax.random.split(jax.random.PRNGKey(0), B)
        A = jnp.stack([jax.random.normal(k, (n, d)) / np.sqrt(n)
                       for k in ks])
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        q = from_least_squares_batch(A, Y, 0.1)
        mesh = jax.make_mesh((8,), ("data",))
        ladder = doubling_ladder(m_max)
        cache = ShardLadderCache.from_mesh("gaussian", keys, q, ladder,
                                           mesh)
        inj = ShardLossInjector(cache, shard=5, at_segment=2)
        x, s = segmented_padded_solve_batched(
            q, keys, m_max=m_max, method="pcg", tol=1e-10,
            segment_trips=4, gram_hvp=True, grams=cache.total(),
            on_segment=inj)
        assert inj.fired_at == 2, inj.fired_at
        assert len(cache.alive) == 7
        st = np.asarray(s["status"])
        assert np.all(st == int(SolveStatus.OK)), st
        err = float(jnp.max(jnp.abs(x - direct_solve(q))))
        assert err < 1e-3, err
        print("SHARDLOSS8_OK", err)
    """)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, cwd=str(root), timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    assert "SHARDLOSS8_OK" in r.stdout
