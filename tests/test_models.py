"""Per-arch smoke tests (reduced configs, one forward + one train step on
CPU, shape + finiteness asserts) and decode/prefill consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, get_config
from repro.models import (
    build_cross_cache,
    encode,
    forward,
    init_cache,
    init_params,
)
from repro.train import AdamWConfig, TrainConfig, init_opt_state
from repro.train.step import make_train_step

ARCH_IDS = list(ALIASES)


def _setup(arch, S=16, B=2):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    enc = (
        jax.random.normal(jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model))
        if cfg.n_enc_layers else None
    )
    return cfg, params, tokens, enc


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg, params, tokens, enc = _setup(arch)
    logits, _ = forward(params, cfg, tokens, enc_feats=enc,
                        compute_dtype=jnp.float32)
    assert logits.shape == (2, 16, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg, params, tokens, enc = _setup(arch)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=2,
                                       total_steps=10),
                       num_microbatches=1, compute_dtype=jnp.float32)
    step = jax.jit(make_train_step(cfg, tcfg))
    opt = init_opt_state(params)
    batch = {
        "tokens": tokens,
        "labels": jnp.roll(tokens, -1, axis=1),
        "mask": jnp.ones(tokens.shape, jnp.float32),
    }
    if enc is not None:
        batch["enc_feats"] = enc
    params2, opt2, metrics = step(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    delta = sum(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert delta > 0


@pytest.mark.parametrize("arch", [
    "gemma2-27b",            # ring-buffer local + global alternation
    "recurrentgemma-9b",     # RG-LRU state + local attn
    "rwkv6-3b",              # pure recurrent state
    "whisper-small",         # enc-dec + cross cache
    "qwen2-7b",              # plain GQA full cache
])
def test_decode_matches_prefill(arch):
    """Step-by-step decode == full-sequence forward (cache correctness)."""
    S, B = 12, 2
    cfg, params, tokens, enc = _setup(arch, S=S, B=B)
    ref_logits, _ = forward(params, cfg, tokens, enc_feats=enc,
                            compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.n_enc_layers:
        enc_out = encode(params, cfg, enc, compute_dtype=jnp.float32)
        cc = build_cross_cache(params, cfg, enc_out)
        for nm in cc["blocks"]:
            cache["blocks"][nm] = cache["blocks"][nm] | cc["blocks"][nm]
        for nm in cc["rem"]:
            cache["rem"][nm] = cache["rem"][nm] | cc["rem"][nm]
    outs = []
    for t in range(S):
        lg, cache = forward(params, cfg, tokens[:, t:t + 1], cache=cache,
                            cache_pos=jnp.asarray(t, jnp.int32),
                            compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref_logits),
                               rtol=2e-3, atol=2e-3)


def test_moe_exact_with_full_capacity():
    """Routing math is exact when capacity is non-binding (drops are the
    only prefill/decode divergence)."""
    cfg = get_config("mixtral-8x22b").reduced()
    cfg = dataclasses.replace(
        cfg, capacity_factor=float(cfg.n_experts) / cfg.top_k + 0.01
    )
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=16)
    B, S = 2, 12
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    ref_logits, _ = forward(params, cfg, tokens, compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        lg, cache = forward(params, cfg, tokens[:, t:t + 1], cache=cache,
                            cache_pos=jnp.asarray(t, jnp.int32),
                            compute_dtype=jnp.float32)
        outs.append(lg[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(ref_logits), rtol=1e-4, atol=1e-4)


def test_prefill_then_decode_continues():
    """Multi-token prefill into cache, then decode continues consistently."""
    from repro.serve.step import prefill_step, decode_step

    cfg = get_config("gemma2-27b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    B, S = 2, 40  # > reduced window (32) to exercise ring prefill
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0, cfg.vocab)
    # reference: full forward over S+1 tokens
    ref_logits, _ = forward(params, cfg, toks, compute_dtype=jnp.float32)
    cache = init_cache(cfg, B, S + 8, dtype=jnp.float32)
    _, cache = prefill_step(params, cfg, toks[:, :S], cache,
                            compute_dtype=jnp.float32)
    lg, _ = decode_step(params, cfg, toks[:, S:S + 1], cache,
                        jnp.asarray(S, jnp.int32),
                        compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(ref_logits[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_param_counts_match_published():
    expect = {
        "internvl2-2b": (1.7e9, 2.2e9),
        "gemma2-27b": (26e9, 29e9),
        "qwen2-7b": (7.0e9, 8.0e9),
        "mixtral-8x22b": (135e9, 145e9),
        "qwen2-moe-a2.7b": (13.5e9, 15.0e9),
        "rwkv6-3b": (2.7e9, 3.3e9),
        "recurrentgemma-9b": (8.0e9, 10.0e9),
    }
    for arch, (lo, hi) in expect.items():
        n = get_config(arch).param_count()
        assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_active_params_moe():
    cfg = get_config("qwen2-moe-a2.7b")
    assert cfg.active_param_count() < 0.35 * cfg.param_count()
