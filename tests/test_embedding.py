"""Subspace-embedding properties and concentration (paper §2.2, §5).
Hypothesis property tests on sketch invariants live in test_properties.py
(optional dep)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import effective_dimension, make_sketch
from repro.core.effective_dim import (
    exp_decay_singular_values,
    m_delta_gaussian,
    m_delta_srht,
)


def test_sketch_unbiased():
    """E[SᵀS] = I for all three embeddings (Monte-Carlo over seeds)."""
    n, m, reps = 64, 256, 64
    for kind in ["gaussian", "srht", "sjlt"]:
        acc = np.zeros((n, n))
        for r in range(reps):
            S = make_sketch(kind, m, n, jax.random.PRNGKey(r)).dense()
            acc += np.asarray(S.T @ S)
        acc /= reps
        err = np.max(np.abs(acc - np.eye(n)))
        assert err < 0.25, f"{kind}: E[SᵀS] deviates by {err}"


def test_srht_is_orthogonal_transform():
    """H·E is orthogonal ⇒ SRHT preserves norms in expectation exactly."""
    n, d = 256, 16
    A = jax.random.normal(jax.random.PRNGKey(0), (n, d))
    sk = make_sketch("srht", n, n, jax.random.PRNGKey(1))
    # with m = n (all rows, w/o replacement) ‖SA‖_F² == ‖A‖_F²·(n/m)
    SA = sk.apply(A)
    np.testing.assert_allclose(
        float(jnp.sum(SA**2)), float(jnp.sum(A**2)), rtol=0.35
    )


def test_embedding_deviation_scaling(ridge_problem):
    """‖C_S − I‖₂ shrinks ~1/√m (eq. 5.4): doubling m⁴ roughly halves²."""
    q = ridge_problem["q"]
    H = q.A.T @ q.A + (q.nu**2) * jnp.diag(q.lam_diag)
    w, V = jnp.linalg.eigh(H)
    Hmh = (V * (w**-0.5)[None, :]) @ V.T
    devs = []
    for m in [64, 256, 1024]:
        vals = []
        for seed in range(3):
            sk = make_sketch("gaussian", m, q.n, jax.random.PRNGKey(seed))
            SA = sk.apply(q.A)
            H_S = SA.T @ SA + (q.nu**2) * jnp.diag(q.lam_diag)
            C = Hmh @ H_S @ Hmh
            vals.append(float(jnp.linalg.norm(C - jnp.eye(q.d), 2)))
        devs.append(np.mean(vals))
    assert devs[2] < devs[0] / 2.0  # 16× more rows ⇒ ≥2× tighter


def test_m_delta_formulas_monotone():
    for d_e in [10.0, 100.0, 1000.0]:
        assert m_delta_gaussian(d_e) < m_delta_srht(d_e, n=1 << 20)
    assert m_delta_gaussian(100) > m_delta_gaussian(10)
    assert m_delta_srht(100, 1 << 16) > m_delta_srht(10, 1 << 16)


def test_effective_dimension_limits():
    sv = exp_decay_singular_values(512, 0.99)
    d_e_small_nu = float(effective_dimension(sv, 1e-6))
    d_e_large_nu = float(effective_dimension(sv, 10.0))
    assert d_e_small_nu > 400  # ν→0 ⇒ d_e → rank
    assert d_e_large_nu < 60   # large ν ⇒ small d_e
    # d_e ≤ d always
    assert d_e_small_nu <= 512 + 1e-3
