"""Hypothesis property tests on kernel/sketch invariants.

Kept in their own module behind ``pytest.importorskip`` so the suite
degrades gracefully where the optional dev dependency is absent
(``pip install -e .[dev]`` provides it); the deterministic oracle tests
live in test_kernels.py / test_embedding.py and always run."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import fwht, make_sketch  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.kernels.fwht import fwht_pallas  # noqa: E402


@settings(max_examples=10, deadline=None)
@given(
    lg_n=st.integers(min_value=3, max_value=10),
    d=st.integers(min_value=1, max_value=16),
    seed=st.integers(min_value=0, max_value=2**30),
)
def test_fwht_kernel_property(lg_n, d, seed):
    n = 1 << lg_n
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    got = fwht_pallas(x, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref.fwht_ref(x)),
                               rtol=1e-4, atol=1e-4)
    # Parseval: ‖Hx‖² = n‖x‖²
    np.testing.assert_allclose(float(jnp.sum(got**2)),
                               n * float(jnp.sum(x**2)), rtol=1e-3)


@settings(max_examples=20, deadline=None)
@given(
    lg_n=st.integers(min_value=1, max_value=9),
    d=st.integers(min_value=1, max_value=8),
    seed=st.integers(min_value=0, max_value=2**30),
)
def test_fwht_involution_property(lg_n, d, seed):
    """H(Hx) = n·x — the Hadamard transform is an involution up to n."""
    n = 1 << lg_n
    x = jax.random.normal(jax.random.PRNGKey(seed), (n, d))
    hx = fwht(x, axis=0)
    hhx = fwht(hx, axis=0)
    np.testing.assert_allclose(np.asarray(hhx), n * np.asarray(x),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(min_value=8, max_value=200),
    m=st.integers(min_value=1, max_value=64),
    seed=st.integers(min_value=0, max_value=2**30),
)
def test_sjlt_column_norms(n, m, seed):
    """Every SJLT column has exactly s=1 entry of magnitude 1."""
    S = make_sketch("sjlt", m, n, jax.random.PRNGKey(seed)).dense()
    S = np.asarray(S)
    col_counts = (np.abs(S) > 0).sum(axis=0)
    np.testing.assert_array_equal(col_counts, np.ones(n))
    np.testing.assert_allclose(np.abs(S).sum(axis=0), np.ones(n), rtol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(min_value=0, max_value=2**30))
def test_sketch_linearity(seed):
    """S(aX + bY) = a·SX + b·SY for all sketch kinds."""
    n, d, m = 64, 8, 32
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    X = jax.random.normal(k1, (n, d))
    Y = jax.random.normal(k2, (n, d))
    for kind in ["gaussian", "srht", "sjlt"]:
        sk = make_sketch(kind, m, n, jax.random.PRNGKey(seed // 2))
        lhs = sk.apply(2.0 * X - 3.0 * Y)
        rhs = 2.0 * sk.apply(X) - 3.0 * sk.apply(Y)
        np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs),
                                   rtol=1e-4, atol=1e-4)
