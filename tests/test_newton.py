"""Sketched-Newton GLM layer (DESIGN.md §8): objectives vs autodiff,
adaptive Newton vs exact-IRLS references for every family (acceptance:
B≥8 logistic batch matches IRLS to ≤1e-4 in x), warm-started ladder
semantics, the quadratic-family consistency anchor, and the GLM serving
path with Newton-level certificates."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive_padded import doubling_ladder
from repro.core.effective_dim import (
    effective_dimension_exact,
    effective_dimension_weighted_exact,
)
from repro.core.newton import (
    adaptive_newton_solve,
    adaptive_newton_solve_batched,
    irls_reference,
    newton_cg_reference,
)
from repro.core.objectives import (
    GLM_FAMILIES,
    get_objective,
    glm_grad_and_weights,
    glm_value,
)
from repro.core.quadratic import (
    _as_batched_reg,
    direct_solve,
    from_least_squares_batch,
)


def _rel_rows(a, b):
    return np.max(np.linalg.norm(np.asarray(a - b), axis=1)
                  / (np.linalg.norm(np.asarray(b), axis=1) + 1e-30))


def logistic_batch(B, n, d, seed=0, scale=1.0):
    from repro.core.objectives import synthetic_logistic_batch

    return synthetic_logistic_batch(jax.random.PRNGKey(seed), B, n, d,
                                    scale=scale)


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family", GLM_FAMILIES)
def test_objective_grad_and_weights_match_autodiff(family):
    """∇F and the Hessian weights ℓ'' agree with jax autodiff of the
    scalar objective — per family, on a small batch."""
    obj = get_objective(family)
    B, n, d = 3, 40, 6
    A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d)) / np.sqrt(d)
    y = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (B, n)))
    if family == "logistic":
        y = (y > 0.7).astype(jnp.float32)
    elif family == "poisson":
        y = jnp.floor(y * 2)
    x = 0.3 * jax.random.normal(jax.random.PRNGKey(2), (B, d))
    nu_b, lam_b = _as_batched_reg(0.2, None, B, d, jnp.float32)

    g, w = glm_grad_and_weights(obj, A, y, nu_b, lam_b, x)
    g_ad = jax.grad(
        lambda xx: jnp.sum(glm_value(obj, A, y, nu_b, lam_b, xx)))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ad),
                               rtol=1e-4, atol=1e-5)
    # ℓ'' = d(ℓ')/dt elementwise (huber's kink: check off the boundary)
    t = jnp.einsum("bnd,bd->bn", A, x)
    d2_ad = jax.vmap(jax.vmap(jax.grad(
        lambda tt, yy: obj.dloss(tt, yy))))(t, y)
    np.testing.assert_allclose(np.asarray(w), np.asarray(d2_ad),
                               rtol=1e-4, atol=1e-5)
    assert bool(jnp.all(w >= 0))


def test_get_objective_spellings():
    assert get_objective("huber:0.5").name == "huber[0.5]"
    obj = get_objective("logistic")
    assert get_objective(obj) is obj
    with pytest.raises(ValueError):
        get_objective("probit")


# ---------------------------------------------------------------------------
# Adaptive sketched Newton vs exact references
# ---------------------------------------------------------------------------

def test_acceptance_logistic_batch_matches_irls():
    """Acceptance criterion: a B=8 logistic-ridge batch through
    ``adaptive_newton_solve_batched`` (inner = padded engine, warm-started
    per-problem ladders) matches the exact-IRLS reference to ≤1e-4 in x,
    with every problem's decrement certificate below tolerance."""
    B, n, d = 8, 400, 24
    A, Y = logistic_batch(B, n, d, seed=0)
    x, stats = adaptive_newton_solve_batched(
        "logistic", A, Y, 0.3, m_max=64, keys=jax.random.PRNGKey(5))
    x_ref = irls_reference("logistic", A, Y, 0.3)
    assert _rel_rows(x, x_ref) < 1e-4
    assert bool(np.all(np.asarray(stats["converged"])))
    assert stats["m_trajectory"].shape[1] == B
    # the m trajectory is the per-step inner m_final — all on the ladder
    ladder = set(doubling_ladder(64)) | {0}
    assert set(stats["m_trajectory"].ravel().tolist()) <= ladder


@pytest.mark.parametrize("family,nu", [("poisson", 0.3), ("huber", 0.3)])
def test_newton_other_families_match_irls(family, nu):
    B, n, d = 4, 300, 12
    if family == "poisson":
        ks = jax.random.split(jax.random.PRNGKey(21), 3)
        A = jax.random.normal(ks[0], (B, n, d)) / np.sqrt(d)
        xt = 0.3 * jax.random.normal(ks[1], (B, d))
        lam = jnp.exp(jnp.einsum("bnd,bd->bn", A, xt))
        Y = jax.random.poisson(ks[2], lam).astype(jnp.float32)
    else:
        ks = jax.random.split(jax.random.PRNGKey(31), 3)
        A = jax.random.normal(ks[0], (B, n, d)) / np.sqrt(d)
        Y = jnp.einsum("bnd,bd->bn", A, 0.5 * jnp.ones((B, d))) + (
            0.1 * jax.random.normal(ks[1], (B, n)))
    x, stats = adaptive_newton_solve_batched(
        family, A, Y, nu, m_max=32, keys=jax.random.PRNGKey(6))
    x_ref = irls_reference(family, A, Y, nu)
    assert _rel_rows(x, x_ref) < 1e-4, family
    assert bool(np.all(np.asarray(stats["converged"])))


def test_quadratic_family_is_the_ridge_anchor():
    """family="quadratic" reproduces the ridge solution (W ≡ 1 makes every
    Newton system the original (1.1); the first full step lands on it)."""
    B, n, d = 4, 300, 16
    A = jax.random.normal(jax.random.PRNGKey(9), (B, n, d)) / np.sqrt(n)
    Y = jax.random.normal(jax.random.PRNGKey(10), (B, n))
    x, stats = adaptive_newton_solve_batched(
        "quadratic", A, Y, 0.2, m_max=32)
    x_star = direct_solve(from_least_squares_batch(A, Y, 0.2))
    assert _rel_rows(x, x_star) < 1e-4
    assert int(np.max(np.asarray(stats["newton_iters"]))) <= 3


def test_single_problem_wrapper():
    A, Y = logistic_batch(1, 200, 8, seed=4)
    x, stats = adaptive_newton_solve("logistic", A[0], Y[0], 0.3, m_max=32,
                                     key=jax.random.PRNGKey(2))
    assert x.shape == (8,)
    assert stats["m_trajectory"].ndim == 1
    assert float(stats["decrement"]) <= 1e-9
    xb, _ = adaptive_newton_solve_batched(
        "logistic", A, Y, 0.3, m_max=32, keys=jax.random.PRNGKey(2))
    # same fixed point regardless of key plumbing
    assert np.linalg.norm(np.asarray(x - xb[0])) < 1e-3


def test_warm_started_ladder_levels_carry_across_steps():
    """The adaptive-Newton-sketch warm start: pass an ill-conditioned
    problem whose first Newton step climbs the ladder; subsequent steps
    must START from the discovered level (their inner doublings are
    bounded by what remains above it), visible as a non-decreasing per-
    step m trajectory."""
    B, n, d = 3, 512, 48
    ks = jax.random.split(jax.random.PRNGKey(11), B)
    As, Ys = [], []
    for i in range(B):
        kA, kx, ky = jax.random.split(ks[i], 3)
        # decaying spectrum so the ladder has somewhere to stop below cap
        U, _ = jnp.linalg.qr(jax.random.normal(kA, (n, d)))
        sv = 0.9 ** jnp.arange(d, dtype=jnp.float32)
        A = (U * sv[None, :]) @ jnp.linalg.qr(
            jax.random.normal(kx, (d, d)))[0].T
        p = jax.nn.sigmoid(4.0 * A @ jax.random.normal(ky, (d,)))
        Ys.append((jax.random.uniform(jax.random.fold_in(ky, 1), (n,)) < p
                   ).astype(jnp.float32))
        As.append(A)
    A, Y = jnp.stack(As), jnp.stack(Ys)
    x, stats = adaptive_newton_solve_batched(
        "logistic", A, Y, 0.05, m_max=128, keys=jax.random.PRNGKey(3))
    traj = stats["m_trajectory"]
    for b in range(B):
        ms = [m for m in traj[:, b] if m > 0]
        assert ms == sorted(ms), (b, ms)       # warm start: never re-climbs
    x_ref = irls_reference("logistic", A, Y, 0.05)
    assert _rel_rows(x, x_ref) < 1e-3


def test_newton_cg_reference_agrees():
    A, Y = logistic_batch(2, 200, 8, seed=13)
    x_cg = newton_cg_reference("logistic", A, Y, 0.3)
    x_ref = irls_reference("logistic", A, Y, 0.3)
    assert _rel_rows(x_cg, x_ref) < 1e-4


# ---------------------------------------------------------------------------
# Weighted effective dimension (satellite)
# ---------------------------------------------------------------------------

def test_weighted_effective_dimension():
    A = jax.random.normal(jax.random.PRNGKey(0), (200, 16)) / np.sqrt(200)
    nu = 0.1
    d_e = effective_dimension_exact(A, nu)
    d_e_w1 = effective_dimension_weighted_exact(A, jnp.ones((200,)), nu)
    assert abs(d_e - d_e_w1) < 1e-4          # W = I recovers the unweighted
    # scaling all weights by c rescales the spectrum like scaling A by √c:
    # heavier weights ⇒ larger Gram ⇒ larger d_e (ν fixed)
    d_e_up = effective_dimension_weighted_exact(
        A, 4.0 * jnp.ones((200,)), nu)
    assert d_e_up > d_e_w1
    # zero weights on half the rows = effective dimension of the kept half
    w = jnp.concatenate([jnp.ones((100,)), jnp.zeros((100,))])
    d_e_half = effective_dimension_weighted_exact(A, w, nu)
    d_e_half_direct = effective_dimension_exact(A[:100], nu)
    assert abs(d_e_half - d_e_half_direct) < 1e-4


# ---------------------------------------------------------------------------
# GLM serving path
# ---------------------------------------------------------------------------

def test_solver_service_glm_certificates():
    from repro.serve.solver_service import GLMSolution, ShapeClass, SolverService

    svc = SolverService(batch_size=4, sketch="gaussian",
                        shape_classes=(ShapeClass(256, 32, 64),
                                       ShapeClass(1024, 64, 128)))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(5):
        n = int(rng.integers(80, 900))
        d = int(rng.integers(8, 50))
        kA, kx, ky = jax.random.split(jax.random.PRNGKey(100 + i), 3)
        A = jax.random.normal(kA, (n, d)) / np.sqrt(d)
        p = jax.nn.sigmoid(A @ jax.random.normal(kx, (d,)))
        y = (jax.random.uniform(ky, (n,)) < p).astype(jnp.float32)
        nu = float(rng.uniform(0.2, 0.5))
        rid = svc.submit_glm(A, y, nu, family="logistic")
        reqs.append((rid, A, y, nu))
    # ridge and glm traffic can coexist in one flush
    rid_ridge = svc.submit(jnp.asarray(np.ones((100, 8)) / 10.0),
                           jnp.ones((100,)), 0.3)
    sols = svc.flush()
    assert len(sols) == 6
    assert not isinstance(sols[rid_ridge], GLMSolution)
    for rid, A, y, nu in reqs:
        s = sols[rid]
        assert isinstance(s, GLMSolution)
        assert s.x.shape == (A.shape[1],)
        assert s.family == "logistic" and s.converged
        assert s.newton_iters >= 1 and len(s.m_trajectory) >= 1
        assert s.m_final == s.m_trajectory[-1]
        assert s.decrement <= svc.newton_tol
        x_ref = irls_reference("logistic", A[None], y[None], nu)[0]
        rel = float(np.linalg.norm(np.asarray(s.x - x_ref))
                    / np.linalg.norm(np.asarray(x_ref)))
        assert rel < 1e-3, (rid, rel)
    assert all(not v for v in svc._glm_queues.values())


def test_solver_service_glm_validates():
    from repro.serve.solver_service import SolverService

    svc = SolverService()
    A = jnp.ones((64, 8)) / 8.0
    y = jnp.ones((64,))
    with pytest.raises(ValueError):
        svc.submit_glm(A, y, 0.0, family="logistic")   # ν = 0 rejected
    with pytest.raises(ValueError):
        svc.submit_glm(A, y, 0.3, family="probit")     # unknown family
