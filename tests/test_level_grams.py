"""Ladder-level Gram providers (core/level_grams.py): every family's level
Grams vs a dense (S_m A)ᵀ(S_m A) oracle at ALL ladder levels (incl. a
non-pow2 cap), chunk-size bit-identity of the streamed Gaussian, the
no-(B, m_max, n)-intermediate streaming guarantee (jaxpr shape scan), and
the SRHT family end-to-end through the batched adaptive engine."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import (
    count_primitive,
    has_intermediate_of_shape,
    max_intermediate_bytes,
)
from repro.core.adaptive_padded import (
    doubling_ladder,
    padded_adaptive_solve_batched,
)
from repro.core.effective_dim import exp_decay_singular_values
from repro.core.level_grams import PADDED_SKETCHES, get_provider
from repro.core.quadratic import Quadratic, direct_solve, from_least_squares_batch
from repro.kernels import ref
from repro.kernels.gaussian_gram import gaussian_s_dense, gaussian_sa_ref

B, N, D, M_MAX = 3, 300, 12, 24          # ladder (1,2,4,8,16,24): non-pow2 cap
LADDER = doubling_ladder(M_MAX)


def _rel_fro(got, want):
    return float(np.linalg.norm(got - want) / (np.linalg.norm(want) + 1e-30))


@pytest.fixture(scope="module")
def q3():
    A = jax.random.normal(jax.random.PRNGKey(0), (B, N, D)) / np.sqrt(N)
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, N))
    return from_least_squares_batch(A, Y, jnp.asarray([0.1, 0.2, 0.3]))


@pytest.fixture(scope="module")
def keys():
    return jax.random.split(jax.random.PRNGKey(42), B)


def _dense_S_levels(sketch, data, n, ladder):
    """Materialize each problem's dense level-m sketch S_m (m, n) for every
    ladder level, straight from the family's documented definition."""
    m_max = ladder[-1]
    out = {m: [] for m in ladder}
    for b in range(B):
        if sketch in ("gaussian", "gaussian_dense"):
            S = np.asarray(gaussian_s_dense(data["seeds"][b: b + 1],
                                            m_max, n))[0]
            for m in ladder:
                out[m].append(S[:m] / np.sqrt(m))
        elif sketch == "sjlt":
            u = np.asarray(data["u"][b])
            signs = np.asarray(data["signs"][b])
            M = 1 << (m_max - 1).bit_length()
            for m in ladder:
                if m & (m - 1) == 0:                 # pow2: ⌊u·m⌋
                    rows = np.clip(np.floor(u * m).astype(int), 0, m - 1)
                else:                                # cap: fold the tail of M
                    rM = np.clip(np.floor(u * M).astype(int), 0, M - 1)
                    rows = np.where(rM < m, rM, rM - m)
                S = np.zeros((m, n), np.float32)
                S[rows, np.arange(n)] = signs
                out[m].append(S)
        elif sketch == "srht":
            signs = np.asarray(data["signs"][b])
            rows = np.asarray(data["rows"][b])
            n_pad = 1 << max(0, (n - 1).bit_length())
            H = np.asarray(ref.hadamard_dense(n_pad))
            E = np.zeros((n_pad, n), np.float32)
            E[np.arange(n), np.arange(n)] = signs
            for m in ladder:
                out[m].append(H[rows[:m]] @ E / np.sqrt(m))
        else:
            raise AssertionError(sketch)
    return out


@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_level_grams_match_dense_oracle(q3, keys, sketch):
    """(S_m A)ᵀ(S_m A) from the provider == the materialized-sketch oracle
    at EVERY ladder level, including the non-pow2 cap."""
    provider = get_provider(sketch)
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    grams = np.asarray(provider.level_grams(data, q3, LADDER))
    assert grams.shape == (len(LADDER), B, D, D)
    S_levels = _dense_S_levels(sketch, data, N, LADDER)
    A = np.asarray(q3.A)
    for li, m in enumerate(LADDER):
        for b in range(B):
            SA = S_levels[m][b] @ A[b]
            want = SA.T @ SA
            assert _rel_fro(grams[li, b], want) < 1e-5, (sketch, m, b)


def test_shared_A_matches_per_problem(keys):
    """Shared-A layout produces the same Grams as stacking copies of A."""
    A0 = jax.random.normal(jax.random.PRNGKey(5), (N, D)) / np.sqrt(N)
    Y = jax.random.normal(jax.random.PRNGKey(6), (B, N))
    q_shared = from_least_squares_batch(A0, Y, 0.1)
    q_stack = from_least_squares_batch(
        jnp.broadcast_to(A0, (B, N, D)), Y, 0.1)
    assert q_shared.shared_A and not q_stack.shared_A
    for sketch in PADDED_SKETCHES:
        provider = get_provider(sketch)
        data = provider.sample(keys, M_MAX, N, jnp.float32)
        g_sh = np.asarray(provider.level_grams(data, q_shared, LADDER))
        g_st = np.asarray(provider.level_grams(data, q_stack, LADDER))
        np.testing.assert_allclose(g_sh, g_st, rtol=1e-5, atol=1e-6,
                                   err_msg=sketch)


def test_streamed_gaussian_bit_identical_across_chunks(q3, keys):
    """chunk_cols sets pipelining granularity only: the streamed SA — and
    therefore every level Gram — is bit-for-bit chunk-invariant."""
    seeds = get_provider("gaussian").sample(keys, M_MAX, N, jnp.float32)["seeds"]
    base = gaussian_sa_ref(q3.A, seeds, M_MAX, chunk_cols=256)
    for chunk in (512, 1024, 4096):
        other = gaussian_sa_ref(q3.A, seeds, M_MAX, chunk_cols=chunk)
        assert bool(jnp.all(base == other)), chunk


def test_streamed_gaussian_never_materializes_S(keys):
    """Jaxpr shape scan: no (B, m_max, n) intermediate anywhere in the full
    batched solve with the streamed family — the dense baseline has one.
    Tracing only; nothing here executes."""
    n, m_max = 2048, 128
    A = jax.ShapeDtypeStruct((B, n, D), jnp.float32)
    q = Quadratic(A=A, b=jax.ShapeDtypeStruct((B, D), jnp.float32),
                  nu=jax.ShapeDtypeStruct((B,), jnp.float32),
                  lam_diag=jax.ShapeDtypeStruct((B, D), jnp.float32),
                  batched=True)
    solve = lambda sketch: jax.make_jaxpr(
        lambda q, k: padded_adaptive_solve_batched(
            q, k, m_max=m_max, method="pcg", sketch=sketch)[0])(q, keys)
    streamed = solve("gaussian")
    assert not has_intermediate_of_shape(streamed, (B, m_max, n))
    dense = solve("gaussian_dense")
    assert has_intermediate_of_shape(dense, (B, m_max, n))
    # the largest streamed intermediate is ≥4× below S-sized
    s_bytes = B * m_max * n * 4
    peak, shape = max_intermediate_bytes(streamed)
    assert peak <= s_bytes // 4, (peak, shape)


def test_srht_through_batched_engine():
    """sketch="srht" converges to the direct solve on an ill-conditioned
    batch, with heterogeneous per-problem m_final."""
    Bq, n, d = 3, 512, 64
    rates = [0.5, 0.8, 0.95]
    nus = [0.5, 0.1, 0.05]
    As, Ys = [], []
    for i in range(Bq):
        sv = exp_decay_singular_values(d, rates[i])
        kU, kV, ky = jax.random.split(jax.random.PRNGKey(i), 3)
        U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
        V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
        As.append((U * sv[None, :]) @ V.T)
        Ys.append(jax.random.normal(ky, (n,)))
    q = from_least_squares_batch(jnp.stack(As), jnp.stack(Ys),
                                 jnp.asarray(nus, jnp.float32))
    x, stats = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(3), m_max=256, method="pcg", sketch="srht",
        max_iters=100, rho=0.5, tol=1e-10)
    X = direct_solve(q)
    for i in range(Bq):
        rel = float(jnp.linalg.norm(x[i] - X[i]) / jnp.linalg.norm(X[i]))
        assert rel < 1e-2, (i, rel)
    m_final = np.asarray(stats["m_final"])
    assert len(set(m_final.tolist())) >= 2, m_final
    assert m_final[0] < m_final[-1], m_final


def test_sjlt_cap_single_dispatch(q3, keys):
    """The one-touch guarantee: the SJLT provider issues exactly ONE
    segment-sum dispatch against A even with a non-pow2 cap level (the cap
    Gram is derived by folding the top dispatch's tail rows)."""
    provider = get_provider("sjlt")
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    jx = jax.make_jaxpr(
        lambda q: provider.level_grams(data, q, LADDER))(q3)
    # the dispatch lowers to scatter-add on CPU; exactly one batched
    # dispatch touches A, cap level included
    assert count_primitive(jx, ("scatter-add", "scatter_add")) == 1


def test_provider_registry():
    assert set(PADDED_SKETCHES) == {"gaussian", "gaussian_dense", "sjlt",
                                    "srht"}
    with pytest.raises(ValueError):
        get_provider("nope")


# ---------------------------------------------------------------------------
# Weighted ladders (GLM Newton subproblems, DESIGN.md §8)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def weights3():
    return jax.random.uniform(jax.random.PRNGKey(77), (B, N),
                              minval=0.05, maxval=2.0)


@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_weighted_level_grams_match_dense_oracle(q3, keys, weights3, sketch):
    """With row_weights w, every family's level Grams equal the oracle
    (S_m W^{1/2}A)ᵀ(S_m W^{1/2}A) — the dense sketch applied to the
    materialized weighted matrix — at EVERY ladder level, including the
    non-pow2 cap. (The provider itself never materializes W^{1/2}A; the
    oracle is allowed to.)"""
    provider = get_provider(sketch)
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    qw = q3.with_row_weights(weights3)
    grams = np.asarray(provider.level_grams(data, qw, LADDER))
    S_levels = _dense_S_levels(sketch, data, N, LADDER)
    Aw = np.asarray(jnp.sqrt(weights3)[:, :, None] * q3.A)
    for li, m in enumerate(LADDER):
        for b in range(B):
            SA = S_levels[m][b] @ Aw[b]
            want = SA.T @ SA
            assert _rel_fro(grams[li, b], want) < 1e-5, (sketch, m, b)
    # the explicit kwarg spelling is equivalent to q-carried weights
    g_kw = np.asarray(provider.level_grams(data, q3, LADDER,
                                           row_weights=weights3))
    np.testing.assert_allclose(g_kw, grams, rtol=1e-6, atol=1e-7)


def test_weighted_block_emulation_matches_per_shard_oracle(q3, keys,
                                                           weights3):
    """Sharded path satellite: the weighted BlockEmulationProvider (the
    single-device replica of ``shard_level_grams``'s concatenated block
    sketch) equals the per-shard dense oracle Σ_k (S_k W_k^{1/2}A_k)ᵀ(·),
    and its streamed-gaussian inner matches the dense-gaussian inner
    bit-for-bit (same counter hash per shard)."""
    from repro.core.level_grams import BlockEmulationProvider

    K = 2
    n_loc = N // K
    be_s = BlockEmulationProvider("gaussian", K)
    be_d = BlockEmulationProvider("gaussian_dense", K)
    qw = q3.with_row_weights(weights3)
    data_s = be_s.sample(keys, M_MAX, N, jnp.float32)
    data_d = be_d.sample(keys, M_MAX, N, jnp.float32)
    g_s = np.asarray(be_s.level_grams(data_s, qw, LADDER))
    g_d = np.asarray(be_d.level_grams(data_d, qw, LADDER))
    np.testing.assert_allclose(g_s, g_d, rtol=1e-5, atol=1e-6)
    # per-shard oracle from the sampled seeds
    m_max = LADDER[-1]
    Aw = np.asarray(jnp.sqrt(weights3)[:, :, None] * q3.A)
    want = np.zeros_like(g_s)
    for k, dk in enumerate(data_s["shards"]):
        for b in range(B):
            S = np.asarray(gaussian_s_dense(dk["seeds"][b: b + 1],
                                            m_max, n_loc))[0]
            SA = S @ Aw[b, k * n_loc:(k + 1) * n_loc, :]
            for li, m in enumerate(LADDER):
                seg = SA[:m] / np.sqrt(m)
                want[li, b] += seg.T @ seg
    for li in range(len(LADDER)):
        for b in range(B):
            assert _rel_fro(g_s[li, b], want[li, b]) < 1e-5, (li, b)


def test_weighted_streamed_pass_never_materializes_weighted_A(keys,
                                                              weights3):
    """Jaxpr shape scan (the tentpole's streaming guarantee): the FULL
    weighted batched solve with the streamed gaussian family contains
    neither a (B, m_max, n) sketch nor ANY (B, n, d)-shaped intermediate —
    i.e. no weighted copy of A is ever formed (A itself is an input, not
    an equation output). Tracing only; nothing executes."""
    n, m_max = 2048, 128
    A = jax.ShapeDtypeStruct((B, n, D), jnp.float32)
    w = jax.ShapeDtypeStruct((B, n), jnp.float32)
    q = Quadratic(A=A, b=jax.ShapeDtypeStruct((B, D), jnp.float32),
                  nu=jax.ShapeDtypeStruct((B,), jnp.float32),
                  lam_diag=jax.ShapeDtypeStruct((B, D), jnp.float32),
                  batched=True, row_weights=w)
    jx = jax.make_jaxpr(
        lambda q, k: padded_adaptive_solve_batched(
            q, k, m_max=m_max, method="pcg", sketch="gaussian")[0])(q, keys)
    assert not has_intermediate_of_shape(jx, (B, m_max, n))
    assert not has_intermediate_of_shape(jx, (B, n, D))
    peak, shape = max_intermediate_bytes(jx)
    assert peak <= (B * m_max * n * 4) // 4, (peak, shape)
