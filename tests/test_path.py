"""Regularization-path engine (DESIGN.md §13): the whole λ grid off ONE
one-touch sketch pass.

Covers the acceptance surface of the path mode end to end:

* per-λ path solutions match INDEPENDENT single-λ engine solves to ≤1e-5
  with valid δ̃ certificates, across all four sketch families — including
  the SJLT non-power-of-two ladder cap (m_max=48);
* fp32 path mode is BITWISE-compatible with a loop of single-λ solves at
  a fixed init level (warm start off), both against the shared ladder
  handed in via ``grams=`` and against fully-inline solves that recompute
  it (same keys ⇒ same sketch ⇒ same ladder);
* warm-started level trajectories are monotone along a strong→weak grid;
* the robust wrapper keeps per-point statuses truthful on clean traffic
  and still pays exactly one sketch pass;
* the serving surface: ``submit_path`` certificates, the fingerprint
  ladder cache (cache_hit / sketch_passes=0 / bitwise-identical repeat
  answers, shared between ridge and path traffic), grid validation;
* a forced-8-device SUBPROCESS case (the test_sharded.py pattern):
  sharded path vs replicated path vs direct solves.
"""

import dataclasses
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.adaptive_padded import (
    padded_adaptive_solve_batched,
    padded_path_solve_batched,
    prepare_path_ladder,
)
from repro.core.quadratic import direct_solve, from_least_squares_batch
from repro.core.robust import robust_path_solve_batched
from repro.core.status import SolveStatus
from repro.serve.solver_service import PathSolution, SolverService


def _problem(B, n, d, seed=0):
    A = jax.random.normal(jax.random.PRNGKey(seed), (B, n, d)) / np.sqrt(n)
    Y = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, n))
    q = from_least_squares_batch(A, Y, jnp.full((B,), 1.0, jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(42), B)
    return q, keys


def _rel(a, b):
    return float(jnp.max(jnp.linalg.norm(a - b, axis=-1)
                         / (jnp.linalg.norm(b, axis=-1) + 1e-30)))


def _q_at(q, nu):
    return dataclasses.replace(q, nu=jnp.full((q.batch,), nu, q.b.dtype))


def _run_subprocess(code: str) -> str:
    import os
    from pathlib import Path

    root = Path(__file__).resolve().parents[1]
    env = {**os.environ,
           "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": str(root / "src")}
    r = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                       capture_output=True, text=True, env=env,
                       cwd=str(root), timeout=600)
    assert r.returncode == 0, f"stderr:\n{r.stderr[-3000:]}"
    return r.stdout


# ---------------------------------------------------------------------------
# engine: path vs independent single-λ solves, every family
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("family,m_max", [
    ("gaussian", 64),
    ("gaussian_dense", 64),
    ("sjlt", 64),
    ("srht", 64),
    ("sjlt", 48),        # non-power-of-two ladder cap: [... 32, 48]
])
def test_path_matches_independent_single_lambda(family, m_max):
    """Each λ point of the path matches an INDEPENDENT single-λ engine
    solve of the same problem to ≤1e-5, with finite converged δ̃
    certificates — and the whole grid paid exactly one sketch pass.
    Both sides anchor at the m=d ladder level (init_level=4 ⇒ m=16) so
    the comparison is two deeply-converged solves, not the cold level-0
    certificate corner."""
    B, n, d, P = 3, 1024, 16, 8
    q, keys = _problem(B, n, d)
    nus = jnp.asarray(np.geomspace(1.0, 1e-2, P), jnp.float32)
    lvl = jnp.full((B,), 4, jnp.int32)
    kw = dict(m_max=m_max, method="pcg", sketch=family, max_iters=200,
              tol=1e-12)

    xs, stats = padded_path_solve_batched(q, keys, nus, init_level=lvl, **kw)
    assert stats["sketch_passes"] == 1
    dt = np.asarray(stats["dtilde"])
    assert np.all(np.isfinite(dt)) and dt.max() <= 1e-9, dt.max()

    for p in range(P):
        q_p = _q_at(q, float(nus[p]))
        x_ref, _ = padded_adaptive_solve_batched(q_p, keys, init_level=lvl,
                                                 **kw)
        assert _rel(xs[p], x_ref) <= 1e-5, (p, _rel(xs[p], x_ref))
        # absolute anchor (loose at weak λ: x-gap scales like √(δ̃/ν²))
        assert _rel(xs[p], direct_solve(q_p)) <= 1e-3, p


def test_warm_start_level_trajectories_monotone():
    """Warm-starting the per-problem sketch level means a grid walked
    strong→weak never re-climbs the ladder: level trajectories are
    monotone non-decreasing along the path."""
    B, n, d, m_max, P = 3, 1024, 16, 64, 8
    q, keys = _problem(B, n, d)
    nus = jnp.asarray(np.geomspace(1.0, 1e-2, P), jnp.float32)
    _, stats = padded_path_solve_batched(
        q, keys, nus, m_max=m_max, method="pcg", max_iters=200, tol=1e-12)
    lv = np.asarray(stats["level"])
    assert lv.shape == (P, B)
    assert np.all(np.diff(lv, axis=0) >= 0), lv


def test_path_bitwise_matches_looped_single_lambda_fp32():
    """fp32 path mode with warm start OFF is bit-identical to a per-λ
    loop of single-λ solves at the same fixed init level — both when the
    loop is handed the shared λ-free ladder (``grams=``) and when each
    loop point recomputes it inline (same keys ⇒ same sketch ⇒ the same
    ladder, bit for bit)."""
    B, n, d, m_max, P = 3, 512, 16, 32, 5
    q, _ = _problem(B, n, d, seed=10)
    keys = jax.random.split(jax.random.PRNGKey(3), B)
    nus = jnp.asarray(np.geomspace(1.0, 1e-2, P), jnp.float32)
    lvl = jnp.full((B,), 3, jnp.int32)
    kw = dict(m_max=m_max, method="pcg", sketch="gaussian", max_iters=200,
              tol=1e-12)

    xs, _ = padded_path_solve_batched(q, keys, nus, init_level=lvl,
                                      warm_start=False, **kw)
    grams, gfull = prepare_path_ladder(q, keys, m_max=m_max,
                                       sketch="gaussian")
    for p in range(P):
        q_p = _q_at(q, float(nus[p]))
        x_shared, _ = padded_adaptive_solve_batched(
            q_p, keys, init_level=lvl, grams=grams, gram_full=gfull, **kw)
        x_inline, _ = padded_adaptive_solve_batched(
            q_p, keys, init_level=lvl, **kw)
        assert np.array_equal(np.asarray(xs[p]), np.asarray(x_shared)), p
        assert np.array_equal(np.asarray(xs[p]), np.asarray(x_inline)), p


def test_robust_path_clean_traffic():
    """The robust wrapper on clean data: every point OK/converged, zero
    retries, zero fallbacks — and still exactly one sketch pass for the
    whole grid."""
    B, n, d, m_max, P = 3, 512, 16, 32, 4
    q, keys = _problem(B, n, d, seed=5)
    nus = jnp.asarray(np.geomspace(1.0, 0.05, P), jnp.float32)
    xs, stats = robust_path_solve_batched(
        q, keys, nus, m_max=m_max, method="pcg", max_iters=200, tol=1e-10)
    assert int(stats["sketch_passes"]) == 1
    assert xs.shape == (P, B, d)
    assert np.all(np.asarray(stats["status"]) == SolveStatus.OK.value)
    assert np.all(np.asarray(stats["converged"]))
    assert np.all(np.asarray(stats["retries"]) == 0)
    assert not np.any(np.asarray(stats["fell_back"]))


# ---------------------------------------------------------------------------
# service: submit_path certificates, the fingerprint ladder cache
# ---------------------------------------------------------------------------

def _ridge_data(n, d, seed):
    A = np.asarray(jax.random.normal(jax.random.PRNGKey(seed),
                                     (n, d))) / np.sqrt(n)
    y = np.asarray(jax.random.normal(jax.random.PRNGKey(seed + 1), (n,)))
    return A, y


def test_service_path_certificates():
    """submit_path → flush returns per-λ PathPoints carrying the full
    certificate surface, solutions agree with direct solves, and one
    packed chunk pays one sketch pass for every grid in it."""
    svc = SolverService(batch_size=4, tol=1e-10)
    nus = tuple(np.geomspace(1.0, 0.05, 6))
    rids = []
    for i in range(3):
        A, y = _ridge_data(256, 32, seed=100 + 2 * i)
        rids.append(svc.submit_path(A, y, nus))
    sols = svc.flush()
    assert svc.stats["path_requests"] == 3
    for i, rid in enumerate(rids):
        sol = sols[rid]
        assert isinstance(sol, PathSolution)
        assert sol.status == "OK" and sol.converged
        assert sol.sketch_passes == 1 and not sol.cache_hit
        assert len(sol.points) == 6
        A, y = _ridge_data(256, 32, seed=100 + 2 * i)
        for pt in sol.points:
            assert pt.converged and np.isfinite(pt.delta_tilde)
            x_ref = np.linalg.solve(
                A.T @ A + pt.nu ** 2 * np.eye(32), A.T @ y)
            rel = (np.linalg.norm(np.asarray(pt.x) - x_ref)
                   / np.linalg.norm(x_ref))
            assert rel <= 1e-4, (rid, pt.nu, rel)


def test_service_ladder_cache_repeat_path():
    """Repeat-identical path traffic under ``ladder_cache=True``: the
    second submit of the same (A, y, grid) is served off the cached
    λ-free ladder — cache_hit=True, sketch_passes=0, and (because slot
    sketch keys derive from the content fingerprint) the answers are
    BITWISE identical to the first round."""
    svc = SolverService(batch_size=4, tol=1e-10, ladder_cache=True)
    A, y = _ridge_data(256, 32, seed=7)
    nus = tuple(np.geomspace(1.0, 0.05, 5))

    rid1 = svc.submit_path(A, y, nus)
    cold = svc.flush()[rid1]
    assert not cold.cache_hit and cold.sketch_passes == 1

    rid2 = svc.submit_path(A, y, nus)
    warm = svc.flush()[rid2]
    assert warm.cache_hit and warm.sketch_passes == 0
    assert warm.converged
    assert svc.stats["sketch_passes_saved"] >= 1
    for p_cold, p_warm in zip(cold.points, warm.points):
        assert np.array_equal(np.asarray(p_cold.x), np.asarray(p_warm.x))
        assert p_cold.delta_tilde == p_warm.delta_tilde


def test_service_ladder_cache_shared_with_ridge():
    """The fingerprint is λ-FREE, so ridge traffic on data a path request
    already sketched hits the same cache entry: the single-λ solve skips
    its sketch pass and records cache_hit on its RidgeSolution."""
    svc = SolverService(batch_size=4, tol=1e-10, ladder_cache=True)
    A, y = _ridge_data(256, 32, seed=11)
    svc.flush()  # no-op on empty queues
    rid_path = svc.submit_path(A, y, tuple(np.geomspace(1.0, 0.1, 4)))
    assert svc.flush()[rid_path].sketch_passes == 1

    rid_ridge = svc.submit(A, y, nu=0.3)
    sol = svc.flush()[rid_ridge]
    assert sol.cache_hit and sol.converged
    x_ref = np.linalg.solve(A.T @ A + 0.3 ** 2 * np.eye(32), A.T @ y)
    rel = np.linalg.norm(np.asarray(sol.x) - x_ref) / np.linalg.norm(x_ref)
    assert rel <= 1e-4, rel


def test_service_path_grid_validation():
    """Admission validates EVERY grid point's ν: strict mode raises on a
    ν=0 anywhere in the grid, lenient mode quarantines the request and
    returns a REJECTED PathSolution (sketch_passes=0) at flush; an empty
    grid always raises."""
    A, y = _ridge_data(256, 32, seed=13)
    strict = SolverService(batch_size=4)
    with pytest.raises(ValueError):
        strict.submit_path(A, y, (1.0, 0.0, 0.1))
    with pytest.raises(ValueError):
        strict.submit_path(A, y, ())

    lenient = SolverService(batch_size=4, strict=False)
    rid = lenient.submit_path(A, y, (1.0, 0.0, 0.1))
    sol = lenient.flush()[rid]
    assert sol.status == SolveStatus.REJECTED.name
    assert not sol.converged and sol.sketch_passes == 0
    assert all(p.status == SolveStatus.REJECTED.name for p in sol.points)


# ---------------------------------------------------------------------------
# sharded path (forced 8 devices, subprocess)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_sharded_path_matches_replicated():
    """The path engine on a K=8 mesh: the same per-shard one-touch pass +
    ONE psum serves the entire grid (sketch_passes=1), and every λ point
    agrees with the replicated path (different sketch law, same optimum)
    to ≤1e-5 and with direct solves to ≤1e-4."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.core.adaptive_padded import padded_path_solve_batched
        from repro.core.quadratic import direct_solve, \\
            from_least_squares_batch

        mesh = jax.make_mesh((8,), ("data",))
        B, n, d, m_max, P = 3, 1024, 16, 64, 4
        A = jax.random.normal(jax.random.PRNGKey(0), (B, n, d)) / np.sqrt(n)
        Y = jax.random.normal(jax.random.PRNGKey(1), (B, n))
        q = from_least_squares_batch(A, Y, jnp.full((B,), 1.0, jnp.float32))
        keys = jax.random.split(jax.random.PRNGKey(42), B)
        nus = jnp.asarray(np.geomspace(1.0, 0.1, P), jnp.float32)
        kw = dict(m_max=m_max, method="pcg", sketch="gaussian",
                  max_iters=200, tol=1e-12)

        xs_sh, st_sh = padded_path_solve_batched(q, keys, nus, mesh=mesh,
                                                 **kw)
        xs_1, _ = padded_path_solve_batched(q, keys, nus, **kw)
        assert st_sh["sketch_passes"] == 1
        dt = np.asarray(st_sh["dtilde"])
        assert np.all(np.isfinite(dt)) and dt.max() <= 1e-9, dt.max()
        rel = lambda a, b: float(jnp.max(
            jnp.linalg.norm(a - b, axis=-1)
            / (jnp.linalg.norm(b, axis=-1) + 1e-30)))
        for p in range(P):
            q_p = dataclasses.replace(
                q, nu=jnp.full((B,), float(nus[p]), jnp.float32))
            assert rel(xs_sh[p], xs_1[p]) <= 1e-5, p
            assert rel(xs_sh[p], direct_solve(q_p)) <= 1e-4, p
        print("PATH_SHARDED_OK")
    """)
    assert "PATH_SHARDED_OK" in out
