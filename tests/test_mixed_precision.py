"""Compute-dtype axis of the one-touch sketch pass (DESIGN.md §10).

Covers, per sketch family where applicable:

* fp32-mode bit-compatibility — ``compute_dtype="fp32"`` is byte-identical
  to the pre-axis default path (no silent numerical drift from plumbing);
* bf16 / int8 provider Grams vs the family's fp32 pass under a tolerance
  model calibrated to the mode (bf16 rounding of the stream operands;
  int8 per-row symmetric quantization of A);
* the int8 per-row-scale exactness bound: |Â − A| ≤ scaleᵢ/2 elementwise;
* chunk-size bit-identity of the streamed gaussian PER dtype (the fixed
  micro-tile order + fp32 accumulator make chunk_cols a pipelining knob
  in every mode, not a numerics knob);
* end-to-end iteration parity — the acceptance criterion: a bf16 ladder
  reaches the same PCG iteration counts (±1) and a δ̃ within 2× of the
  fp32 ladder on all four families, statuses all OK — including the
  weighted GLM Newton path;
* the structural memory win: bf16 never raises any family's peak live
  intermediate, and at serving shapes at least one family (the SRHT's
  (B, n_pad, d) transformed stack) drops below 0.7×;
* serving: the certificate records which mode produced it, and per-class
  overrides beat the service default.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import max_intermediate_bytes
from repro.core.adaptive_padded import (
    doubling_ladder,
    padded_adaptive_solve_batched,
)
from repro.core.level_grams import PADDED_SKETCHES, get_provider
from repro.core.quadratic import Quadratic, from_least_squares_batch
from repro.dist.compress import dequantize_rows, quantize_rows
from repro.kernels.gaussian_gram import gaussian_sa_ref
from repro.kernels.precision import (
    COMPUTE_DTYPES,
    canonical_compute_dtype,
    contract_dtype,
    stream_itemsize,
)

B, N, D, M_MAX = 3, 300, 12, 24
LADDER = doubling_ladder(M_MAX)

# tolerance model: relative Frobenius error of the reduced-precision level
# Grams vs the same provider's fp32 pass. bf16 keeps ~8 mantissa bits on
# the stream operands (accumulation stays fp32), so errors sit at a few
# ×1e-3; int8 adds the per-row quantization of A on top. Bounds are ~5×
# the observed worst case on these shapes.
_GRAM_TOL = {"bf16": 0.03, "int8": 0.06}
REDUCED = ("bf16", "int8")


def _rel_fro(got, want):
    return float(np.linalg.norm(got - want) / (np.linalg.norm(want) + 1e-30))


@pytest.fixture(scope="module")
def q3():
    A = jax.random.normal(jax.random.PRNGKey(0), (B, N, D)) / np.sqrt(N)
    Y = jax.random.normal(jax.random.PRNGKey(1), (B, N))
    return from_least_squares_batch(A, Y, jnp.asarray([0.1, 0.2, 0.3]))


@pytest.fixture(scope="module")
def keys():
    return jax.random.split(jax.random.PRNGKey(42), B)


@pytest.fixture(scope="module")
def weights3():
    return jax.random.uniform(jax.random.PRNGKey(77), (B, N),
                              minval=0.05, maxval=2.0)


# ---------------------------------------------------------------------------
# precision helpers
# ---------------------------------------------------------------------------

def test_canonical_compute_dtype():
    assert canonical_compute_dtype(None) == "fp32"
    assert canonical_compute_dtype("fp32") == "fp32"
    assert canonical_compute_dtype("bf16") == "bf16"
    with pytest.raises(ValueError):
        canonical_compute_dtype("fp16")
    assert contract_dtype("fp32") == jnp.float32
    # int8 codes ∈ [−127, 127] are exact in bf16, so both reduced modes
    # contract in bf16 on the MXU
    assert contract_dtype("bf16") == jnp.bfloat16
    assert contract_dtype("int8") == jnp.bfloat16
    assert [stream_itemsize(c) for c in COMPUTE_DTYPES] == [4, 2, 1]


def test_quantize_rows_exactness_bound():
    """Per-row symmetric int8: the dequantized Â satisfies the half-step
    bound |Â − A| ≤ scaleᵢ/2 elementwise, codes stay in [−127, 127], and
    all-zero rows round-trip to zero (no 0/0 scale)."""
    A = jax.random.normal(jax.random.PRNGKey(3), (7, 33))
    A = A.at[2].set(0.0)                       # degenerate row
    codes, scales = quantize_rows(A)
    assert codes.dtype == jnp.int8 and scales.dtype == jnp.float32
    assert int(jnp.max(jnp.abs(codes))) <= 127
    A_hat = dequantize_rows(codes, scales)
    err = np.abs(np.asarray(A_hat - A))
    bound = np.asarray(scales)[:, None] / 2 + 1e-7
    assert (err <= bound).all()
    assert float(jnp.max(jnp.abs(A_hat[2]))) == 0.0


# ---------------------------------------------------------------------------
# provider Grams per dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_fp32_mode_is_bit_compatible(q3, keys, sketch):
    """compute_dtype="fp32" (and None) is byte-identical to the default
    call — the dtype axis costs the fp32 path nothing."""
    provider = get_provider(sketch)
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    base = provider.level_grams(data, q3, LADDER)
    explicit = provider.level_grams(data, q3, LADDER, compute_dtype="fp32")
    assert bool(jnp.all(base == explicit))


@pytest.mark.parametrize("compute_dtype", REDUCED)
@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_reduced_grams_near_fp32(q3, keys, sketch, compute_dtype):
    """bf16 / int8 level Grams track the fp32 pass within the mode's
    tolerance model at EVERY ladder level, and stay fp32-typed (the
    precision boundary: Grams never leave fp32)."""
    provider = get_provider(sketch)
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    g32 = np.asarray(provider.level_grams(data, q3, LADDER))
    g = provider.level_grams(data, q3, LADDER, compute_dtype=compute_dtype)
    assert g.dtype == jnp.float32
    tol = _GRAM_TOL[compute_dtype]
    for li in range(len(LADDER)):
        for b in range(B):
            rel = _rel_fro(np.asarray(g)[li, b], g32[li, b])
            assert rel < tol, (sketch, compute_dtype, LADDER[li], b, rel)


@pytest.mark.parametrize("compute_dtype", REDUCED)
@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_weighted_reduced_grams_near_fp32(q3, keys, weights3, sketch,
                                          compute_dtype):
    """Same tolerance model with Hessian row weights riding the pass (the
    GLM Newton inner problem): W^{1/2} folds into the per-row scale slot
    of every family, in every mode."""
    provider = get_provider(sketch)
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    qw = q3.with_row_weights(weights3)
    g32 = np.asarray(provider.level_grams(data, qw, LADDER))
    g = np.asarray(provider.level_grams(data, qw, LADDER,
                                        compute_dtype=compute_dtype))
    tol = _GRAM_TOL[compute_dtype]
    for li in range(len(LADDER)):
        for b in range(B):
            rel = _rel_fro(g[li, b], g32[li, b])
            assert rel < tol, (sketch, compute_dtype, LADDER[li], b, rel)


def test_int8_gaussian_matches_dequantized_oracle(q3, keys):
    """The int8 gaussian pass equals the bf16 pass over the dequantized
    Â = codes·scales up to bf16 rounding of the folded column scale — the
    quantization error enters ONLY through Â, never through extra
    precision loss in the fold."""
    provider = get_provider("gaussian")
    data = provider.sample(keys, M_MAX, N, jnp.float32)
    g8 = np.asarray(provider.level_grams(data, q3, LADDER,
                                         compute_dtype="int8"))
    A_hat = jnp.stack([dequantize_rows(*quantize_rows(q3.A[b]))
                       for b in range(B)])
    q_hat = Quadratic(A=A_hat, b=q3.b, nu=q3.nu, lam_diag=q3.lam_diag,
                      batched=True)
    g_hat = np.asarray(provider.level_grams(data, q_hat, LADDER,
                                            compute_dtype="bf16"))
    for li in range(len(LADDER)):
        for b in range(B):
            assert _rel_fro(g8[li, b], g_hat[li, b]) < 0.02, (li, b)


def test_block_emulation_forwards_dtype(q3, keys):
    """The sharded-path emulator (per-shard passes, one combine) runs its
    inner passes at the requested dtype; fp32 mode stays bit-compatible."""
    from repro.core.level_grams import BlockEmulationProvider

    be = BlockEmulationProvider("sjlt", 2)
    data = be.sample(keys, M_MAX, N, jnp.float32)
    g32 = be.level_grams(data, q3, LADDER)
    g32e = be.level_grams(data, q3, LADDER, compute_dtype="fp32")
    assert bool(jnp.all(g32 == g32e))
    gbf = be.level_grams(data, q3, LADDER, compute_dtype="bf16")
    assert gbf.dtype == jnp.float32
    assert _rel_fro(np.asarray(gbf), np.asarray(g32)) < _GRAM_TOL["bf16"]


# ---------------------------------------------------------------------------
# chunk invariance per dtype
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("compute_dtype", COMPUTE_DTYPES)
def test_streamed_gaussian_chunk_invariant_per_dtype(q3, keys, weights3,
                                                     compute_dtype):
    """chunk_cols stays a pipelining-only knob in every mode: the fixed
    micro-tile traversal + fp32 accumulator make the streamed SA
    bit-for-bit identical across chunk sizes for fp32, bf16 AND int8 —
    weighted included."""
    seeds = get_provider("gaussian").sample(keys, M_MAX, N,
                                            jnp.float32)["seeds"]
    for w in (None, weights3):
        base = gaussian_sa_ref(q3.A, seeds, M_MAX, chunk_cols=256,
                               row_weights=w, compute_dtype=compute_dtype)
        for chunk in (512, 1024, 4096):
            other = gaussian_sa_ref(q3.A, seeds, M_MAX, chunk_cols=chunk,
                                    row_weights=w,
                                    compute_dtype=compute_dtype)
            assert bool(jnp.all(base == other)), (compute_dtype, chunk,
                                                  w is not None)


# ---------------------------------------------------------------------------
# end-to-end iteration parity (the acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sketch", PADDED_SKETCHES)
def test_bf16_ladder_iteration_parity(sketch):
    """The acceptance test: on a CI-scale batch the bf16 sketch pass
    preconditions exactly as well as fp32 — per-problem PCG iteration
    counts within ±1, δ̃ within 2× (+ atol), every status OK, and the
    adapted sketch sizes identical (the δ̃ controller makes the same
    ladder decisions)."""
    Bq, n, d, m_max = 4, 512, 32, 128
    A = jax.random.normal(jax.random.PRNGKey(7), (Bq, n, d)) / np.sqrt(n)
    Y = jax.random.normal(jax.random.PRNGKey(8), (Bq, n))
    q = from_least_squares_batch(A, Y, jnp.asarray([0.3, 0.1, 0.05, 0.2]))
    keys = jax.random.split(jax.random.PRNGKey(9), Bq)

    run = lambda cd: padded_adaptive_solve_batched(
        q, keys, m_max=m_max, method="pcg", sketch=sketch, max_iters=200,
        rho=0.5, tol=1e-10, compute_dtype=cd)
    x32, s32 = run("fp32")
    xbf, sbf = run("bf16")
    assert np.asarray(s32["status"]).max() == 0          # all OK
    assert np.asarray(sbf["status"]).max() == 0
    it32 = np.asarray(s32["iters"])
    itbf = np.asarray(sbf["iters"])
    assert np.abs(itbf - it32).max() <= 1, (sketch, it32, itbf)
    d32 = np.asarray(s32["dtilde"])
    dbf = np.asarray(sbf["dtilde"])
    assert (dbf <= 2.0 * d32 + 1e-12).all(), (sketch, d32, dbf)
    np.testing.assert_array_equal(np.asarray(sbf["m_final"]),
                                  np.asarray(s32["m_final"]))
    # both land on the same solution to solver tolerance
    assert float(jnp.max(jnp.linalg.norm(xbf - x32, axis=1))) < 1e-4


def test_int8_ladder_converges():
    """int8 feeds quantized features through the same controller: looser
    than the bf16 parity claim (quantization perturbs A itself), but the
    solve must still reach OK everywhere with a comparable ladder."""
    Bq, n, d, m_max = 4, 512, 32, 128
    A = jax.random.normal(jax.random.PRNGKey(7), (Bq, n, d)) / np.sqrt(n)
    Y = jax.random.normal(jax.random.PRNGKey(8), (Bq, n))
    q = from_least_squares_batch(A, Y, 0.2)
    x32, s32 = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(9), m_max=m_max, method="pcg",
        sketch="gaussian", max_iters=200, tol=1e-10)
    x8, s8 = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(9), m_max=m_max, method="pcg",
        sketch="gaussian", max_iters=200, tol=1e-10, compute_dtype="int8")
    assert np.asarray(s8["status"]).max() == 0
    assert np.abs(np.asarray(s8["iters"])
                  - np.asarray(s32["iters"])).max() <= 2
    assert float(jnp.max(jnp.linalg.norm(x8 - x32, axis=1))) < 1e-4


def test_glm_newton_bf16_parity():
    """The weighted path end-to-end: a logistic batch through the adaptive
    sketched-Newton driver at bf16 matches the fp32 run's outer iteration
    counts (±1), converges everywhere, and agrees with the exact IRLS
    reference — Hessian weights ride the reduced-precision pass without
    costing Newton steps."""
    from repro.core.newton import adaptive_newton_solve_batched, irls_reference
    from repro.core.objectives import synthetic_logistic_batch

    Bq, n, d = 4, 400, 16
    A, Y = synthetic_logistic_batch(jax.random.PRNGKey(0), Bq, n, d)
    run = lambda cd: adaptive_newton_solve_batched(
        "logistic", A, Y, 0.3, m_max=64, keys=jax.random.PRNGKey(5),
        compute_dtype=cd)
    x32, s32 = run("fp32")
    xbf, sbf = run("bf16")
    assert bool(np.all(np.asarray(s32["converged"])))
    assert bool(np.all(np.asarray(sbf["converged"])))
    assert np.abs(np.asarray(sbf["newton_iters"])
                  - np.asarray(s32["newton_iters"])).max() <= 1
    x_ref = irls_reference("logistic", A, Y, 0.3)
    rel = np.max(np.linalg.norm(np.asarray(xbf - x_ref), axis=1)
                 / (np.linalg.norm(np.asarray(x_ref), axis=1) + 1e-30))
    assert rel < 1e-3, rel


# ---------------------------------------------------------------------------
# the structural memory win
# ---------------------------------------------------------------------------

def test_bf16_never_raises_and_srht_shrinks_peak_bytes(keys):
    """Jaxpr shape scan of the full sketch pass at a serving shape: bf16
    never produces a LARGER peak live intermediate than fp32 for any
    family, and the SRHT — whose (B, n_pad, d) transformed stack IS the
    peak — drops below 0.7× (measured 0.5×). Tracing only."""
    n, d, m_max = 2048, 64, 128
    ladder = doubling_ladder(m_max)
    A = jax.ShapeDtypeStruct((B, n, d), jnp.float32)
    q = Quadratic(A=A, b=jax.ShapeDtypeStruct((B, d), jnp.float32),
                  nu=jax.ShapeDtypeStruct((B,), jnp.float32),
                  lam_diag=jax.ShapeDtypeStruct((B, d), jnp.float32),
                  batched=True)
    ratios = {}
    for sketch in PADDED_SKETCHES:
        provider = get_provider(sketch)

        def sketch_pass(q, keys, cd):
            data = provider.sample(keys, m_max, n, jnp.float32)
            return provider.level_grams(data, q, ladder, compute_dtype=cd)

        peak32, _ = max_intermediate_bytes(jax.make_jaxpr(
            lambda q, k: sketch_pass(q, k, "fp32"))(q, keys))
        peakbf, shape = max_intermediate_bytes(jax.make_jaxpr(
            lambda q, k: sketch_pass(q, k, "bf16"))(q, keys))
        assert peakbf <= peak32, (sketch, peakbf, peak32, shape)
        ratios[sketch] = peakbf / peak32
    assert ratios["srht"] < 0.7, ratios


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def test_service_certificates_record_compute_dtype():
    """A bf16 service stamps every ridge certificate with the mode that
    produced it, converges, and a per-class override beats the service
    default."""
    from repro.serve.solver_service import ShapeClass, SolverService

    classes = (ShapeClass(256, 32, 64),                       # inherits bf16
               ShapeClass(1024, 64, 128, compute_dtype="fp32"))
    svc = SolverService(batch_size=4, sketch="gaussian",
                        compute_dtype="bf16", shape_classes=classes)
    rng = np.random.default_rng(0)
    want = {}
    for i in range(6):
        n = int(rng.integers(64, 900))
        d = int(rng.integers(8, 60))
        A = jax.random.normal(jax.random.PRNGKey(2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (n,))
        rid = svc.submit(A, y, nu=0.3)
        want[rid] = "bf16" if (n <= 256 and d <= 32) else "fp32"
    sols = svc.flush()
    assert len(sols) == 6
    for rid, s in sols.items():
        assert s.converged, rid
        assert s.compute_dtype == want[rid], (rid, s.compute_dtype)
        assert s.delta_tilde == s.delta_tilde          # fp32 certificate
