"""Batch-polymorphic core + multi-problem padded engine (DESIGN.md §6):
batched vs looped single-problem agreement, shared-A λ-batch fast path,
independent per-problem doubling, batched SJLT kernel, solver service."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import direct_solve, factorize, from_least_squares, run_fixed
from repro.core.adaptive_padded import (
    doubling_ladder,
    padded_adaptive_solve,
    padded_adaptive_solve_batched,
)
from repro.core.effective_dim import exp_decay_singular_values
from repro.core.precond import factorize_shared
from repro.core.quadratic import (
    Quadratic,
    from_least_squares_batch,
    lambda_sweep,
    stack_quadratics,
)
from repro.core.sketches import make_sketch


def _rel(a, b):
    return float(jnp.linalg.norm(a - b) / (jnp.linalg.norm(b) + 1e-30))


@pytest.fixture(scope="module")
def batch32():
    """B=32 heterogeneous ridge problems: mixed spectra (mixed effective
    dimensions) and mixed ν — each problem wants a different sketch size."""
    B, n, d = 32, 256, 32
    ks = jax.random.split(jax.random.PRNGKey(0), B)
    As, Ys, nus = [], [], []
    for i in range(B):
        rate = 0.82 + 0.16 * (i / (B - 1))
        sv = exp_decay_singular_values(d, rate)
        kU, kV, ky = jax.random.split(ks[i], 3)
        U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
        V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
        As.append((U * sv[None, :]) @ V.T)
        Ys.append(jax.random.normal(ky, (n,)))
        nus.append(0.05 + 0.05 * (i % 4))
    A, Y = jnp.stack(As), jnp.stack(Ys)
    q = from_least_squares_batch(A, Y, jnp.asarray(nus, jnp.float32))
    return {"q": q, "A": A, "Y": Y, "keys": jax.random.split(
        jax.random.PRNGKey(42), B), "m_max": 64}


# ---------------------------------------------------------------------------
# Batched core ops
# ---------------------------------------------------------------------------

def test_batched_direct_solve_matches_loop(batch32):
    q = batch32["q"]
    X = direct_solve(q)
    for i in [0, 7, 31]:
        x_i = direct_solve(q.problem(i))
        assert _rel(X[i], x_i) < 1e-5


def test_shared_A_lambda_sweep_matches_independent(batch32):
    A0, y0 = batch32["A"][0], batch32["Y"][0]
    nus = jnp.asarray([0.05, 0.1, 0.2, 0.4], jnp.float32)
    q_sweep = lambda_sweep(A0, y0, nus)
    assert q_sweep.shared_A
    X = direct_solve(q_sweep)
    for i in range(len(nus)):
        x_i = direct_solve(from_least_squares(A0, y0, nus[i]))
        assert _rel(X[i], x_i) < 1e-5
    # value/error reductions are per-problem vectors
    assert q_sweep.value(X).shape == (len(nus),)


def test_batched_run_fixed_matches_loop(batch32):
    q = batch32["q"]
    B, n, d = q.batch, q.n, q.d
    SA = jnp.stack([
        make_sketch("gaussian", 2 * d, n, jax.random.PRNGKey(100 + i)).apply(
            q.A[i]) for i in range(B)])
    P = factorize(SA, q.nu, q.lam_diag)
    x, trace = run_fixed(q, P, jnp.zeros((B, d)), method="pcg", iters=25,
                         rho=0.5)
    assert trace.shape == (25, B)
    for i in [0, 15, 31]:
        Pi = factorize(SA[i], q.nu[i], q.lam_diag[i])
        xi, _ = run_fixed(q.problem(i), Pi, jnp.zeros((d,)), method="pcg",
                          iters=25, rho=0.5)
        assert _rel(x[i], xi) < 1e-4


def test_factorize_shared_lambda_batch(batch32):
    """Shared-SA λ-batch preconditioner matches per-λ factorizations."""
    A0, y0 = batch32["A"][0], batch32["Y"][0]
    nus = jnp.asarray([0.05, 0.1, 0.3], jnp.float32)
    q_sweep = lambda_sweep(A0, y0, nus)
    sk = make_sketch("gaussian", 2 * q_sweep.d, q_sweep.n,
                     jax.random.PRNGKey(5))
    SA = sk.apply(A0)
    P = factorize_shared(SA, q_sweep.nu, q_sweep.lam_diag)
    z = jax.random.normal(jax.random.PRNGKey(6), (len(nus), q_sweep.d))
    v = P.solve(z)
    for i in range(len(nus)):
        Pi = factorize(SA, nus[i], q_sweep.lam_diag[i])
        np.testing.assert_allclose(np.asarray(v[i]), np.asarray(Pi.solve(z[i])),
                                   rtol=2e-4, atol=1e-3)


def test_stack_quadratics_roundtrip(batch32):
    q = batch32["q"]
    qs = [q.problem(i) for i in range(4)]
    qb = stack_quadratics(qs)
    assert qb.batched and qb.batch == 4
    v = jax.random.normal(jax.random.PRNGKey(1), (4, q.d))
    hv = qb.hvp(v)
    for i in range(4):
        np.testing.assert_allclose(np.asarray(hv[i]),
                                   np.asarray(qs[i].hvp(v[i])),
                                   rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Multi-problem padded engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,sketch", [
    ("ihs", "gaussian"), ("pcg", "gaussian"), ("pcg", "sjlt"),
    ("polyak", "gaussian"),
])
def test_batched_engine_matches_single_solves(batch32, method, sketch):
    """Acceptance: B=32 through the engine matches per-problem single solves
    to ≤1e-5 relative error, with identical per-problem doubling schedules
    and per-problem (not global) m_final values.

    A problem whose δ̃ lands exactly on the accept/reject threshold can flip
    its schedule between the B=32 and B=1 executables (last-ulp einsum
    differences); such a problem still converges, just along a different
    valid schedule — allow at most 2/32 of those, at a looser 1e-4."""
    q, keys, m_max = batch32["q"], batch32["keys"], batch32["m_max"]
    # tol=0 makes the stop deterministic (a fixed iteration budget): with a
    # δ̃-relative stop, the final iteration count flips on last-ulp noise
    # between the B=32 and B=1 executables and the solutions differ by the
    # size of one final polishing step. Both runs polish to the f32 floor
    # and return their best iterate.
    xb, sb = padded_adaptive_solve_batched(
        q, keys, m_max=m_max, method=method, sketch=sketch, max_iters=60,
        rho=0.5, tol=0.0)
    assert sb["m_final"].shape == (q.batch,)
    schedule_flips = 0
    for i in range(q.batch):
        q1 = Quadratic(A=q.A[i][None], b=q.b[i][None], nu=q.nu[i][None],
                       lam_diag=q.lam_diag[i][None], batched=True)
        x1, s1 = padded_adaptive_solve_batched(
            q1, keys[i][None], m_max=m_max, method=method, sketch=sketch,
            max_iters=60, rho=0.5, tol=0.0)
        assert _rel(xb[i], x1[0]) <= 1e-5, i
        if int(sb["m_final"][i]) != int(s1["m_final"][0]):
            # a δ̃ landing exactly on the accept/reject threshold can flip
            # the doubling schedule between executables; the solution still
            # matches (asserted above), so allow a couple of these
            schedule_flips += 1
    assert schedule_flips <= 2, schedule_flips


def test_batched_engine_correct_vs_direct(batch32):
    q, keys, m_max = batch32["q"], batch32["keys"], batch32["m_max"]
    X = direct_solve(q)
    xb, _ = padded_adaptive_solve_batched(
        q, keys, m_max=m_max, method="pcg", sketch="gaussian",
        max_iters=100, rho=0.5, tol=1e-12)
    for i in range(q.batch):
        assert _rel(xb[i], X[i]) < 1e-4


def test_independent_doubling_mixed_effective_dims():
    """Problems with very different effective dimensions adapt to different
    m_final inside ONE compiled batch — no global sketch size."""
    # Hardness (steepness of decay relative to ν) increases with index:
    # the easy head should stay at a tiny sketch while the hard one doubles
    # all the way up. (A flat spectrum would be EASY for PCG even at m=1 —
    # the adaptive test correctly leaves such problems unsketched.)
    B, n, d = 3, 512, 64
    rates = [0.5, 0.8, 0.95]
    nus = [0.5, 0.1, 0.05]
    As, Ys = [], []
    for i in range(B):
        sv = exp_decay_singular_values(d, rates[i])
        kU, kV, ky = jax.random.split(jax.random.PRNGKey(i), 3)
        U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
        V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
        As.append((U * sv[None, :]) @ V.T)
        Ys.append(jax.random.normal(ky, (n,)))
    q = from_least_squares_batch(jnp.stack(As), jnp.stack(Ys),
                                 jnp.asarray(nus, jnp.float32))
    x, stats = padded_adaptive_solve_batched(
        q, jax.random.PRNGKey(3), m_max=256, method="pcg", sketch="gaussian",
        max_iters=100, rho=0.5, tol=1e-10)
    m_final = np.asarray(stats["m_final"])
    assert len(set(m_final.tolist())) >= 2, m_final
    # easiest problem needs a smaller sketch than the hardest
    assert m_final[0] < m_final[-1], m_final
    X = direct_solve(q)
    for i in range(B):
        assert _rel(x[i], X[i]) < 1e-2, i


def test_padded_engine_shared_A_lambda_batch(batch32):
    """Shared-A λ-batch through the engine matches per-λ single solves."""
    A0, y0 = batch32["A"][0], batch32["Y"][0]
    nus = jnp.asarray([0.05, 0.1, 0.2, 0.4], jnp.float32)
    q_sweep = lambda_sweep(A0, y0, nus)
    keys = jax.random.split(jax.random.PRNGKey(9), len(nus))
    x, stats = padded_adaptive_solve_batched(
        q_sweep, keys, m_max=64, method="pcg", sketch="gaussian",
        max_iters=100, rho=0.5, tol=1e-12)
    for i in range(len(nus)):
        x_i = direct_solve(from_least_squares(A0, y0, nus[i]))
        assert _rel(x[i], x_i) < 1e-4, i


def test_padded_engine_matrix_rhs(batch32):
    """A (d, c) matrix RHS dispatches as a shared-A column batch."""
    A0 = batch32["A"][0]
    Y = jax.random.normal(jax.random.PRNGKey(11), (A0.shape[0], 3))
    q = from_least_squares(A0, Y, 0.1)
    X, stats = padded_adaptive_solve(q, jax.random.PRNGKey(12), m_max=64,
                                     method="pcg", tol=1e-12)
    assert X.shape == q.b.shape
    assert stats["m_final"].shape == (3,)
    X_star = direct_solve(q)
    assert _rel(X, X_star) < 1e-4


def test_doubling_ladder():
    assert doubling_ladder(8) == (1, 2, 4, 8)
    assert doubling_ladder(12) == (1, 2, 4, 8, 12)
    assert doubling_ladder(1) == (1,)


def test_polyak_padded_engine_agrees_with_host_adaptive(batch32):
    """Satellite regression: ``polyak`` now dispatches through the padded
    engine (it previously only existed in the host-orchestrated
    ``adaptive_solve``). Host and engine draw different sketch randomness,
    so agreement is at the solution level: both converge to the direct
    solve, hence to each other."""
    from repro.core.adaptive import AdaptiveConfig, adaptive_solve

    q, keys = batch32["q"], batch32["keys"]
    B_small = 4
    xs_direct = direct_solve(q)
    for i in range(B_small):
        q1 = q.problem(i)
        res = adaptive_solve(
            q1, AdaptiveConfig(method="polyak", sketch="gaussian",
                               m_max=64, max_iters=150, tol=1e-12),
            key=keys[i])
        qb = Quadratic(A=q.A[i][None], b=q.b[i][None], nu=q.nu[i][None],
                       lam_diag=q.lam_diag[i][None], batched=True)
        xp, sp = padded_adaptive_solve_batched(
            qb, keys[i][None], m_max=64, method="polyak", sketch="gaussian",
            max_iters=150, rho=0.5, tol=1e-12)
        assert _rel(res.x, xs_direct[i]) < 1e-4, i       # host converges
        assert _rel(xp[0], xs_direct[i]) < 1e-4, i       # engine converges
        assert _rel(xp[0], res.x) < 2e-4, i              # hence agree
        assert int(sp["m_final"][0]) <= 64


# ---------------------------------------------------------------------------
# Batched SJLT kernel (interpret mode = TPU semantics on CPU)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shared", [False, True])
def test_sjlt_kernel_batched_matches_ref(shared):
    from repro.kernels import ref
    from repro.kernels.sjlt import sjlt_pallas, sjlt_pallas_batched

    B, n, d, m, br = 3, 300, 17, 32, 128
    A = jax.random.normal(jax.random.PRNGKey(1), (n, d) if shared
                          else (B, n, d))
    rows = jax.random.randint(jax.random.PRNGKey(2), (B, n), 0, m)
    signs = jax.random.rademacher(jax.random.PRNGKey(3), (B, n),
                                  dtype=jnp.float32)
    got = sjlt_pallas_batched(A, rows, signs, m, interpret=True,
                              block_rows=br)
    want = ref.sjlt_ref_batched(A, rows, signs, m)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
    # per-problem slices agree with the single-problem kernel
    A0 = A if shared else A[0]
    w0 = sjlt_pallas(A0, rows[0], signs[0], m, interpret=True, block_rows=br)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(w0),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Solver service
# ---------------------------------------------------------------------------

def test_solver_service_buckets_and_certificates():
    from repro.serve.solver_service import ShapeClass, SolverService

    svc = SolverService(batch_size=4, sketch="gaussian", tol=1e-12,
                        shape_classes=(ShapeClass(256, 32, 64),
                                       ShapeClass(1024, 64, 128)))
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(6):
        n = int(rng.integers(64, 900))
        d = int(rng.integers(8, 60))
        A = jax.random.normal(jax.random.PRNGKey(i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(50 + i), (n,))
        nu = float(rng.uniform(0.1, 0.4))
        rid = svc.submit(A, y, nu)
        reqs.append((rid, A, y, nu))
    sols = svc.flush()
    assert len(sols) == 6
    for rid, A, y, nu in reqs:
        s = sols[rid]
        assert s.x.shape == (A.shape[1],)
        x_star = direct_solve(from_least_squares(A, y, nu))
        assert _rel(s.x, x_star) < 1e-4
        assert s.m_final <= s.shape_class.m_max
        assert s.delta_tilde >= 0.0
    assert svc.stats["requests"] == 6
    # every queue drained
    assert all(not v for v in svc._queues.values())


def test_solver_service_rejects_oversize():
    from repro.serve.solver_service import ShapeClass, SolverService

    svc = SolverService(shape_classes=(ShapeClass(128, 16, 32),))
    with pytest.raises(ValueError):
        svc.submit(jnp.ones((256, 8)), jnp.ones((256,)), 0.1)
