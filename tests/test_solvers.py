"""Solver correctness: IHS / PCG / Polyak / CG / adaptive vs direct solve,
convergence-rate assertions (Thm 3.2 / eq. 3.3), and Theorem 4.1 bounds."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    direct_solve,
    factorize,
    from_least_squares,
    k_max,
    make_sketch,
    run_fixed,
)
from repro.core.adaptive_padded import padded_adaptive_solve
from repro.core.effective_dim import m_delta_gaussian


def _rel_err(x, x_star):
    return float(jnp.linalg.norm(x - x_star) / jnp.linalg.norm(x_star))


@pytest.mark.parametrize("method", ["ihs", "pcg", "polyak"])
@pytest.mark.parametrize("kind", ["gaussian", "srht", "sjlt"])
def test_fixed_sketch_converges(ridge_problem, method, kind):
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    m = 4 * int(ridge_problem["d_e"])  # comfortably above d_e
    sk = make_sketch(kind, m, q.n, jax.random.PRNGKey(3))
    P = factorize(sk.apply(q.A), q.nu, q.lam_diag)
    x, trace = run_fixed(q, P, jnp.zeros((q.d,)), method=method,
                         iters=40, rho=0.5)
    assert _rel_err(x, x_star) < 1e-3
    # δ̃ decreased monotonically-ish (allow small numerical jitter at floor)
    tr = np.asarray(trace)
    assert tr[-1] < tr[0] * 1e-4


def test_ihs_rate_matches_theory(ridge_problem):
    """Thm 3.2: conditional on E_ρ, δ_t ≤ ρ^t δ_0. With m large the measured
    per-step contraction must beat the theoretical ρ for the effective
    deviation. Use m = n/2 (ρ_eff small)."""
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    m = q.n // 2
    sk = make_sketch("gaussian", m, q.n, jax.random.PRNGKey(4))
    P = factorize(sk.apply(q.A), q.nu, q.lam_diag)
    rho = 0.5
    x, trace = run_fixed(q, P, jnp.zeros((q.d,)), method="ihs",
                         iters=10, rho=rho)
    tr = np.asarray(trace)
    ratios = tr[1:] / tr[:-1]
    # c(α,ρ)·φ(ρ) per-step bound on δ̃ ratios (Cor 2.5)
    assert np.all(ratios[:5] < (1 + np.sqrt(rho)) / (1 - np.sqrt(rho)) * rho)


def test_pcg_beats_ihs(ridge_problem):
    """PCG is optimal among preconditioned first-order methods (Thm 3.3)."""
    q = ridge_problem["q"]
    m = 2 * int(ridge_problem["d_e"])
    sk = make_sketch("gaussian", m, q.n, jax.random.PRNGKey(5))
    P = factorize(sk.apply(q.A), q.nu, q.lam_diag)
    x0 = jnp.zeros((q.d,))
    _, tr_pcg = run_fixed(q, P, x0, method="pcg", iters=15, rho=0.5)
    _, tr_ihs = run_fixed(q, P, x0, method="ihs", iters=15, rho=0.5)
    assert float(tr_pcg[-1]) <= float(tr_ihs[-1]) * 1.01


def test_cg_baseline(ridge_problem):
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    x, _ = cg_solve(q, jnp.zeros((q.d,)), iters=600)
    assert _rel_err(x, x_star) < 1e-2


@pytest.mark.parametrize("method,sketch", [
    ("pcg", "sjlt"), ("pcg", "srht"), ("ihs", "gaussian"),
])
def test_adaptive_converges_and_bounds(ridge_problem, method, sketch):
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    cfg = AdaptiveConfig(method=method, sketch=sketch, max_iters=200,
                         tol=1e-9)
    res = adaptive_solve(q, cfg, key=jax.random.PRNGKey(1))
    assert _rel_err(res.x, x_star) < 1e-2
    # Theorem 4.1: K_t ≤ K_max; m_t ≤ max(m_init, 2·m_δ/ρ) (and ≤ n cap)
    km = k_max(m_delta_gaussian(ridge_problem["d_e"]), cfg.rho, cfg.m_init)
    assert res.n_doublings <= max(km, int(np.ceil(np.log2(q.n))))
    assert res.m_final <= q.n


def test_adaptive_matrix_rhs(ridge_problem):
    """Multi-class (matrix) RHS — the paper's real-data setting."""
    q0 = ridge_problem["q"]
    c = 5
    Y = jax.random.normal(jax.random.PRNGKey(7), (q0.n, c))
    q = from_least_squares(q0.A, Y, q0.nu)
    X_star = direct_solve(q)
    res = adaptive_solve(
        q, AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=100,
                          tol=1e-9),
        key=jax.random.PRNGKey(2),
    )
    assert _rel_err(res.x, X_star) < 1e-2


def test_padded_adaptive(ridge_problem):
    q, x_star = ridge_problem["q"], ridge_problem["x_star"]
    x, stats = padded_adaptive_solve(
        q, jax.random.PRNGKey(9), m_max=512, max_iters=100, rho=0.5,
        tol=1e-10,
    )
    assert _rel_err(x, x_star) < 1e-2
    assert int(stats["m_final"]) <= 512


def test_woodbury_vs_primal():
    """Dual (m<d) and primal (m≥d) factorizations solve the same system.
    ν = 0.3 keeps κ(H_S) ~ 10 so float32 residuals are meaningful; the
    small-ν regime is exercised end-to-end by the solver tests (where PCG
    self-corrects the f32 factorization error)."""
    n, d, nu = 1024, 256, 0.3
    A = jax.random.normal(jax.random.PRNGKey(10), (n, d)) / np.sqrt(n)
    q = from_least_squares(A, jnp.ones((n,)), nu)
    z = jax.random.normal(jax.random.PRNGKey(11), (q.d,))
    sk = make_sketch("gaussian", q.d // 2, q.n, jax.random.PRNGKey(12))
    SA = sk.apply(q.A)
    P_dual = factorize(SA, q.nu, q.lam_diag)
    assert P_dual.mode == "dual"
    H_S = SA.T @ SA + (q.nu ** 2) * jnp.diag(q.lam_diag)
    v = P_dual.solve(z)
    np.testing.assert_allclose(np.asarray(H_S @ v), np.asarray(z),
                               rtol=1e-3, atol=1e-3)
    # and the primal path agrees
    sk2 = make_sketch("gaussian", 2 * q.d, q.n, jax.random.PRNGKey(13))
    P_primal = factorize(sk2.apply(q.A), q.nu, q.lam_diag)
    assert P_primal.mode == "primal"
    v2 = P_primal.solve(z)
    H_S2 = sk2.apply(q.A).T @ sk2.apply(q.A) + (q.nu ** 2) * jnp.diag(q.lam_diag)
    np.testing.assert_allclose(np.asarray(H_S2 @ v2), np.asarray(z),
                               rtol=1e-3, atol=1e-3)
