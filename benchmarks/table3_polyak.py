"""Paper Table 3 / Corollary A.2: the Polyak-IHS finite-time bound
(α(t,ρ)·β_ρ^{ω(t)})^{1/t} for a grid of (ρ, t), and the empirical check
that measured Polyak-IHS contraction beats the bound (it is an upper
bound) while matching the asymptotic rate β_ρ."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import factorize, make_sketch, run_fixed
from .common import emit, synthetic_problem


def bound(t: float, rho: float) -> float:
    """(α(t,ρ)·β_ρ^{ω(t)})^{1/t} in log space (β^300 underflows floats)."""
    sq = math.sqrt(1.0 - rho)
    beta = (1.0 - sq) / (1.0 + sq)
    nu_t = math.log(t) / math.log(2.0) + 1.0
    log_alpha = nu_t * (nu_t + 1.0) * math.log(3.0) + 2.0 * nu_t * math.log(
        1 + 4 * beta + beta**2
    )
    omega = t - 2.0 * nu_t
    return math.exp((log_alpha + omega * math.log(beta)) / t)


def run():
    rows = []
    for rho in [0.1, 0.05, 0.01]:
        for t in [1, 10, 50, 100, 200, 300]:
            rows.append(dict(table="table3", rho=rho, t=t,
                             bound=f"{bound(t, rho):.3g}",
                             faster_than_ihs=bound(t, rho) < rho))
    # empirical: measured per-step rate ≤ bound at t=50. The bound is
    # conditional on E_ρ, so pick d_e small enough (fast decay) that the
    # m = n/2 Gaussian sketch achieves ‖C_S − I‖ ≤ √ρ.
    n, d, nu = 4096, 512, 1e-1
    q, _ = synthetic_problem(n, d, nu, decay=0.9)
    m = n // 2
    sk = make_sketch("gaussian", m, q.n, jax.random.PRNGKey(0))
    P = factorize(sk.apply(q.A), q.nu, q.lam_diag)
    rho = 0.1
    _, tr = run_fixed(q, P, jnp.zeros((d,)), method="polyak", iters=50,
                      rho=rho)
    tr = np.asarray(tr, np.float64)
    # measure the asymptotic rate over the pre-noise-floor segment
    floor = max(tr.min(), 1e-300) * 1e3
    k = int(np.argmax(tr < floor)) or len(tr)
    k = max(k, 5)
    measured = (tr[k - 1] / tr[0]) ** (1.0 / (k - 1))
    rows.append(dict(table="table3", rho=rho, t=int(k),
                     measured_rate=f"{measured:.3g}",
                     bound_asymptotic=f"{bound(300, rho):.3g}",
                     within=bool(measured <= bound(300, rho) * 1.5)))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
