"""Sketch-pass benchmark: wall time + peak live bytes per ladder family.

The padded engine's precompute — randomness → (L, B, d, d) ladder-level
Grams — is the serving hot path's one O(n) touch of A. This benchmark
times exactly that pass for every ``LevelGramProvider`` across n, and
reports two memory numbers per (family, n):

* ``peak_intermediate_bytes`` — the single largest array produced anywhere
  in the jaxpr (sub-jaxprs included; ``repro.analysis.memscan``): the
  dense Gaussian shows its (B, m_max, n) sketch here, the streamed path
  only its (B, m_max, _MICRO) generation tile;
* ``xla_temp_bytes`` — the compiled executable's temp allocation from
  ``memory_analysis()`` (backend-dependent; reported when available).

The acceptance row (n=8192, d=128, m_max=512): ``gaussian`` must complete
where-or-faster than ``gaussian_dense`` with peak live bytes reduced ≥4×.

Dtype axis (DESIGN.md §10): every family is additionally measured at
``compute_dtype ∈ {bf16, int8}`` with per-row ratios against its own fp32
baseline (``speedup_vs_fp32``, ``peak_bytes_ratio_vs_fp32``) plus the
analytic ``stream_item_bytes`` (4/2/1 — the bandwidth axis of the win on
real accelerators). On CPU the wall-clock ratios are advisory (no native
bf16 MXU); the peak-intermediate-bytes reductions are structural: the
SRHT's (B, n_pad, d) transformed stack and the SJLT ref path's (B, n, d)
signed product halve in bf16, and int8 streams 1-byte codes. The gaussian
STREAMED family's peak is its fp32 (L, B, d, d) Gram stack by design —
Grams never leave fp32 — so its ratio is ~1.0: the honest number.

    PYTHONPATH=src python -m benchmarks.bench_sketch_gram [--ns 2048,8192]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.analysis.memscan import max_intermediate_bytes
from repro.core.adaptive_padded import doubling_ladder
from repro.core.level_grams import (COMPUTE_DTYPES, PADDED_SKETCHES,
                                    get_provider)
from repro.core.quadratic import from_least_squares_batch
from repro.kernels.precision import stream_itemsize


def _problem(B: int, n: int, d: int, seed: int):
    kA, kY = jax.random.split(jax.random.PRNGKey(seed))
    A = jax.random.normal(kA, (B, n, d)) / jnp.sqrt(n)
    Y = jax.random.normal(kY, (B, n))
    return from_least_squares_batch(A, Y, 0.1)


def bench_family(sketch: str, B: int, n: int, d: int, m_max: int,
                 reps: int, seed: int, compute_dtype: str = "fp32") -> dict:
    provider = get_provider(sketch)
    q = _problem(B, n, d, seed)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), B)
    ladder = doubling_ladder(m_max)

    def sketch_pass(q, keys):
        data = provider.sample(keys, m_max, q.n, q.A.dtype)
        return provider.level_grams(data, q, ladder,
                                    compute_dtype=compute_dtype)

    jitted = jax.jit(sketch_pass)
    peak, peak_shape = max_intermediate_bytes(
        jax.make_jaxpr(sketch_pass)(q, keys))
    try:
        ma = jitted.lower(q, keys).compile().memory_analysis()
        xla_temp = int(ma.temp_size_in_bytes) if ma is not None else -1
    except Exception:
        xla_temp = -1

    grams = jax.block_until_ready(jitted(q, keys))          # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(jitted(q, keys))
        best = min(best, time.perf_counter() - t0)
    return {
        "bench": "sketch_gram", "sketch": sketch, "B": B, "n": n, "d": d,
        "m_max": m_max, "L": len(ladder), "seed": seed,
        "dtype": compute_dtype,
        "stream_item_bytes": stream_itemsize(compute_dtype),
        "pass_s": round(best, 4),
        "peak_intermediate_bytes": peak,
        "peak_intermediate_shape": "x".join(map(str, peak_shape)),
        "xla_temp_bytes": xla_temp,
        "gram_fro": float(f"{float(jnp.linalg.norm(grams[-1])):.4e}"),
    }


def run(B: int = 4, d: int = 128, m_max: int = 512,
        ns: tuple[int, ...] = (2048, 8192), reps: int = 3,
        seed: int = 0, families: tuple[str, ...] = PADDED_SKETCHES,
        dtypes: tuple[str, ...] = COMPUTE_DTYPES) -> list[dict]:
    rows = []
    for n in ns:
        base = None
        for sketch in families:
            fp32_row = None
            for cd in dtypes:
                row = bench_family(sketch, B, n, d, m_max, reps, seed,
                                   compute_dtype=cd)
                if cd == "fp32":
                    fp32_row = row
                    if sketch == "gaussian":
                        base = row
                    if sketch == "gaussian_dense" and base is not None:
                        row["streamed_speedup"] = round(
                            row["pass_s"] / max(base["pass_s"], 1e-9), 2)
                        row["peak_bytes_ratio"] = round(
                            row["peak_intermediate_bytes"]
                            / max(base["peak_intermediate_bytes"], 1), 1)
                elif fp32_row is not None:
                    # per-family ratios vs its own fp32 baseline
                    row["speedup_vs_fp32"] = round(
                        fp32_row["pass_s"] / max(row["pass_s"], 1e-9), 2)
                    row["peak_bytes_ratio_vs_fp32"] = round(
                        row["peak_intermediate_bytes"]
                        / max(fp32_row["peak_intermediate_bytes"], 1), 3)
                emit(row)
                rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=4)
    ap.add_argument("--d", type=int, default=128)
    ap.add_argument("--m-max", type=int, default=512)
    ap.add_argument("--ns", default="2048,8192",
                    help="comma list of n values")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--dtypes", default=",".join(COMPUTE_DTYPES),
                    help="comma list of compute dtypes (fp32,bf16,int8)")
    args = ap.parse_args()
    run(B=args.B, d=args.d, m_max=args.m_max,
        ns=tuple(int(x) for x in args.ns.split(",")), reps=args.reps,
        dtypes=tuple(args.dtypes.split(",")))


if __name__ == "__main__":
    main()
