"""Segmentation-overhead benchmark: the preemptible solve path vs the
monolithic single-dispatch engine (DESIGN.md §11).

Every production solve now runs through ``segmented_padded_solve_batched``
whenever a deadline / checkpoint / preemption knob is set: the SAME
compiled while_loop body is re-dispatched ``segment_trips`` loop trips at
a time, with the full ``PaddedState`` round-tripping on device and the
host checking wall-clock between dispatches. The cost of that
preemptibility is pure dispatch + host-sync overhead — this benchmark
measures it against ``padded_adaptive_solve_batched`` (one dispatch,
nothing interruptible) on the ``bench_batched.py`` heterogeneous shapes,
at the serving default segment size (32 trips) and a deliberately
fine-grained one (8 trips, the chaos-suite setting).

Budget: ≤ 3% overhead at the default segment size (``overhead_pct`` per
row; each row also records the bitwise agreement — segmentation must never
buy a different answer — and the dispatch count, so a regression in ANY of
the three dimensions is visible in BENCH_solver.json).

    PYTHONPATH=src python benchmarks/bench_resume.py [--B 32] [--reps 3]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.bench_batched import heterogeneous_batch, time_best
from benchmarks.common import emit
from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.quadratic import from_least_squares_batch
from repro.core.robust import segmented_padded_solve_batched

#: overhead budget (percent) at the DEFAULT_SEGMENT_TRIPS granularity —
#: the acceptance bar for making every serving solve preemptible.
BUDGET_PCT = 3.0


def run(B: int = 32, n: int = 512, d: int = 64, m_max: int = 128,
        reps: int = 10, tol: float = 1e-12, seed: int = 42,
        segment_trips: tuple[int, ...] = (32, 8)) -> list[dict]:
    """Emit + return one monolithic row plus one row per segment size.

    ``reps`` defaults high for the same reason ``bench_guard.py``'s does:
    the quantity resolved is a few-percent difference between ~0.1 s
    solves, and best-of-10 per side is what makes the ≤3% budget a
    measurable claim rather than scheduler noise."""
    A, Y, nus = heterogeneous_batch(B, n, d)
    qb = from_least_squares_batch(A, Y, nus)
    keys = jax.random.split(jax.random.PRNGKey(seed), B)

    def mono():
        return padded_adaptive_solve_batched(
            qb, keys, m_max=m_max, method="pcg", sketch="gaussian",
            max_iters=200, rho=0.5, tol=tol)

    def seg(k):
        return segmented_padded_solve_batched(
            qb, keys, m_max=m_max, method="pcg", sketch="gaussian",
            max_iters=200, rho=0.5, tol=tol, segment_trips=k)

    x_ref, s_ref = jax.block_until_ready(mono())    # warm + reference
    t_mono = time_best(lambda: mono()[0], reps)

    base = {"bench": "resume", "method": "pcg", "sketch": "gaussian",
            "B": B, "n": n, "d": d, "m_max": m_max, "seed": seed}
    rows = [{**base, "kind": "monolithic", "time_s": round(t_mono, 4),
             "trips": int(s_ref["trips"])}]
    emit(rows[0])

    for k in segment_trips:
        x_k, s_k = seg(k)                            # warm + correctness
        x_k = jax.block_until_ready(x_k)
        bitwise = bool(jnp.all(x_k == x_ref)) and bool(
            jnp.all(s_k["dtilde"] == s_ref["dtilde"]))
        t_seg = time_best(lambda: seg(k)[0], reps)
        overhead = 100.0 * (t_seg - t_mono) / t_mono
        row = {
            **base, "kind": f"segmented_k{k}",
            "time_s": round(t_seg, 4),
            "monolithic_s": round(t_mono, 4),
            "overhead_pct": round(overhead, 2),
            "bitwise_agreement": bitwise,
            "segments": int(s_k["segments"]),
            "budget_pct": BUDGET_PCT,
            "within_budget": overhead <= BUDGET_PCT,
        }
        emit(row)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=32)
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=128)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=1e-12)
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max, reps=args.reps,
        tol=args.tol)


if __name__ == "__main__":
    main()
