"""Paper Table 2: end-to-end FLOP accounting of adaptive vs non-adaptive
PCG. We count the actual sketch / factorization / iteration flops executed
by each solver run (cost-model from core.sketches/precond — the same
formulas as §4.1) and verify the adaptive advantage predicted by (1.6) vs
(1.7) when d_e ≪ d."""

from __future__ import annotations

import jax

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    effective_dimension,
)
from repro.core.precond import factorization_cost_flops
from repro.core.sketches import sketch_cost_flops
from .common import emit, synthetic_problem


def adaptive_flops(res, kind, n, d):
    """Total flops: per-phase sketch+factorize (m doubles each resketch)
    + per-iteration 4nd (hvp) + min(m,d)·d solves."""
    total = 0.0
    m = res.m_trace[0]
    ms = sorted(set(res.m_trace)) if res.m_trace else [m]
    for m_i in ms:
        total += sketch_cost_flops(kind, m_i, n, d)
        total += factorization_cost_flops(m_i, n, d)
    total += res.iters * (4.0 * n * d + 2.0 * min(res.m_final, d) * d)
    return total


def run(n=8192, d=1024, nu=1e-2):
    # regime-preserving decay (see fig1_synthetic.run): keep d_e ≪ d as in
    # the paper's d=7000 grid
    q, sv = synthetic_problem(n, d, nu, decay=0.995 ** (7000.0 / d))
    d_e = float(effective_dimension(sv, nu))
    rows = []
    for kind in ["sjlt", "srht", "gaussian"]:
        res = adaptive_solve(
            q, AdaptiveConfig(method="pcg", sketch=kind, max_iters=200,
                              tol=1e-8),
            key=jax.random.PRNGKey(0),
        )
        fl_ada = adaptive_flops(res, kind, n, d)
        # non-adaptive baseline: m = 2d, 25 iters (same final accuracy class)
        fl_base = (
            sketch_cost_flops(kind, 2 * d, n, d)
            + factorization_cost_flops(2 * d, n, d)
            + 25 * (4.0 * n * d + 2.0 * d * d)
        )
        rows.append(dict(
            table="table2", kind=kind, d_e=round(d_e), d=d,
            m_final=res.m_final, flops_adaptive=f"{fl_ada:.3g}",
            flops_noada_2d=f"{fl_base:.3g}",
            speedup=round(fl_base / fl_ada, 2),
        ))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
