"""Paper Table 1 / Theorems 5.1–5.2: measured critical sketch sizes vs the
formulas. For each embedding, find (by doubling) the smallest m with
median ‖C_S − I‖₂ ≤ √ρ and compare to the theoretical m_δ/ρ — the theory
is an upper bound, so measured/theory ≤ 1 is the check; the *scaling* in
d_e (not d) is the paper's point and is verified across two ν values."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import effective_dimension, make_sketch
from repro.core.effective_dim import (
    m_delta_gaussian,
    m_delta_sjlt,
    m_delta_srht,
)
from .common import emit, synthetic_problem


def _deviation(q, m, kind, seed):
    sk = make_sketch(kind, m, q.n, jax.random.PRNGKey(seed))
    SA = sk.apply(q.A)
    H = q.A.T @ q.A + (q.nu**2) * jnp.diag(q.lam_diag)
    H_S = SA.T @ SA + (q.nu**2) * jnp.diag(q.lam_diag)
    w, V = jnp.linalg.eigh(H)
    Hmh = (V * (w**-0.5)[None, :]) @ V.T
    C = Hmh @ H_S @ Hmh
    return float(jnp.linalg.norm(C - jnp.eye(q.d), 2))


def run(n=4096, d=512, rho=0.25, reps=3):
    # Consistency note: the measured test is ‖C_S−I‖ ≤ √ρ, but Theorem 5.2
    # guarantees deviation 2√ρ'+ρ' at m = m_δ/ρ'. For the Gaussian bound we
    # therefore invert 2√ρ'+ρ' = √ρ (s² + 2s − √ρ = 0 ⇒ s = √(1+√ρ) − 1)
    # so the theory column is an apples-to-apples upper bound; the SRHT and
    # SJLT rows use the loose O(·) Table-1 forms directly.
    import math as _m
    s_g = _m.sqrt(1.0 + _m.sqrt(rho)) - 1.0
    rho_g = s_g * s_g
    theory = {
        "gaussian": lambda de: m_delta_gaussian(de) / rho_g,
        "srht": lambda de: m_delta_srht(de, n) / rho,
        # m_delta_sjlt is the Table-1 O(d_e²/δ) form with the implicit
        # leading constant taken as EXACTLY 1 (the paper states only the
        # order): the sjlt theory column is an order-of-magnitude upper
        # bound, not a sharp prediction — a different constant would
        # rescale it verbatim. See m_delta_sjlt's docstring.
        "sjlt": lambda de: m_delta_sjlt(de) / rho,
    }
    rows = []
    for nu in [3e-1, 3e-2]:
        q, sv = synthetic_problem(n, d, nu, decay=0.98)
        d_e = float(effective_dimension(sv, nu))
        for kind in ["gaussian", "srht", "sjlt"]:
            m = 8
            while m <= n:
                devs = [_deviation(q, m, kind, s) for s in range(reps)]
                if float(np.median(devs)) <= np.sqrt(rho):
                    break
                m *= 2
            # doubling resolution: the true critical m lies in (m/2, m],
            # so the theory upper bound holds iff m/2 ≤ m_theory
            rows.append(dict(
                table="table1", kind=kind, nu=nu, d_e=round(d_e, 1),
                m_measured=m, m_theory=round(theory[kind](d_e)),
                within_bound=m / 2 <= theory[kind](d_e) * 1.01,
            ))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
