"""Batched multi-problem adaptive engine vs Python loops of single solves.

The serving question (DESIGN.md §6): given B concurrent ridge problems,
is one fully-jitted batched while_loop (per-problem m_t, shared executable)
faster than dispatching B single-problem solves from the host? Two loop
baselines are reported:

* ``host`` — a Python loop over ``core.adaptive.adaptive_solve``, the
  paper-faithful host-orchestrated Algorithm 4.1 and the only way this
  repo could serve B heterogeneous problems before the batched engine
  existed (per-iteration host syncs, per-m_t executables, warmed before
  timing so compilation is excluded);
* ``padded1`` — a *charitable* loop over the compiled B=1 padded engine
  (one executable, reused across problems), isolating pure batching gains
  (jit-call overhead + lost cross-problem vectorization) from the
  host-orchestration overhead the engine also removes.

    PYTHONPATH=src python benchmarks/bench_batched.py [--B 32] [--reps 3]

Emits one CSV-ish row per (method, sketch) with batched/looped seconds and
both speedups, plus correctness columns (max batched-vs-looped relative
error, per-problem m_final spread).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.adaptive import AdaptiveConfig, adaptive_solve
from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.effective_dim import exp_decay_singular_values
from repro.core.quadratic import Quadratic, from_least_squares_batch


def heterogeneous_batch(B: int, n: int, d: int, seed: int = 0):
    """B ridge problems with mixed spectra (mixed effective dimensions) and
    mixed ν — each problem needs a different sketch size."""
    ks = jax.random.split(jax.random.PRNGKey(seed), B)
    As, Ys, nus = [], [], []
    for i in range(B):
        rate = 0.85 + 0.13 * (i / max(B - 1, 1))
        sv = exp_decay_singular_values(d, rate)
        kU, kV, ky = jax.random.split(ks[i], 3)
        U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
        V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
        As.append((U * sv[None, :]) @ V.T)
        Ys.append(jax.random.normal(ky, (n,)))
        nus.append(0.05 + 0.05 * (i % 4))
    return (jnp.stack(As), jnp.stack(Ys), jnp.asarray(nus, jnp.float32))


def time_best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run(B: int = 32, n: int = 512, d: int = 64, m_max: int = 128,
        reps: int = 3, tol: float = 1e-12, seed: int = 42) -> list[dict]:
    """Emit + return one row per (method, sketch) combination."""
    A, Y, nus = heterogeneous_batch(B, n, d)
    qb = from_least_squares_batch(A, Y, nus)
    keys = jax.random.split(jax.random.PRNGKey(seed), B)
    singles = [
        (Quadratic(A=A[i][None], b=qb.b[i][None], nu=nus[i][None],
                   lam_diag=qb.lam_diag[i][None], batched=True),
         keys[i][None])
        for i in range(B)
    ]

    rows = []
    for method, sketch in [("pcg", "gaussian"), ("pcg", "sjlt"),
                           ("pcg", "srht"), ("ihs", "gaussian")]:
        solve = lambda q, k: padded_adaptive_solve_batched(
            q, k, m_max=m_max, method=method, sketch=sketch,
            max_iters=200, rho=0.5, tol=tol)

        xb, sb = jax.block_until_ready(solve(qb, keys))     # warm batched
        jax.block_until_ready(solve(*singles[0]))           # warm B=1 once

        cfg = AdaptiveConfig(method=method, sketch=sketch, rho=0.5,
                             m_max=m_max, max_iters=200, tol=tol)
        host_solve = lambda: [
            adaptive_solve(qb.problem(i), cfg, key=keys[i]).x
            for i in range(B)]
        host_solve()                                        # warm every m_t
        t_host = time_best(host_solve, 1)

        t_batched = time_best(lambda: solve(qb, keys)[0], reps)
        t_looped = time_best(
            lambda: [solve(q1, k1)[0] for q1, k1 in singles], reps)

        rel = 0.0
        m_match = True
        for i, (q1, k1) in enumerate(singles):
            x1, s1 = solve(q1, k1)
            rel = max(rel, float(jnp.linalg.norm(xb[i] - x1[0])
                                 / jnp.linalg.norm(x1[0])))
            m_match &= int(sb["m_final"][i]) == int(s1["m_final"][0])
        mf = np.asarray(sb["m_final"])
        row = {
            "bench": "batched_engine", "method": method, "sketch": sketch,
            "B": B, "n": n, "d": d, "m_max": m_max, "seed": seed,
            "batched_s": round(t_batched, 4),
            "host_loop_s": round(t_host, 4),
            "padded1_loop_s": round(t_looped, 4),
            "speedup_vs_host_loop": round(t_host / t_batched, 2),
            "speedup_vs_padded1_loop": round(t_looped / t_batched, 2),
            "max_rel_err": float(f"{rel:.2e}"),
            "schedules_match": bool(m_match),
            "m_final_min": int(mf.min()), "m_final_max": int(mf.max()),
            "m_final_distinct": len(set(mf.tolist())),
            "max_dtilde": float(f"{float(np.max(np.asarray(sb['dtilde']))):.2e}"),
        }
        emit(row)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=32)
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=128)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=1e-12)
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max, reps=args.reps,
        tol=args.tol)


if __name__ == "__main__":
    main()
