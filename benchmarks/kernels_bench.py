"""Kernel microbenchmarks: FWHT / SJLT wrappers vs jnp oracles on CPU
(wall-time here is the *oracle* path — the Pallas path is TPU-target and
is validated for semantics in interpret mode; see tests/test_kernels.py).
Reports us_per_call + achieved effective GB/s for the CPU oracle."""

from __future__ import annotations

import time

import jax

from repro.kernels import ref
from repro.kernels.ops import sjlt_apply
from .common import emit


def _time(fn, *args, iters=5):
    fn(*args).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    out.block_until_ready()
    return (time.perf_counter() - t0) / iters


def run():
    rows = []
    for n, d in [(4096, 256), (16384, 512)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        f = jax.jit(ref.fwht_ref)
        dt = _time(f, x)
        nbytes = n * d * 4 * (n.bit_length() - 1)
        rows.append(dict(bench="fwht_ref", n=n, d=d,
                         us_per_call=round(dt * 1e6, 1),
                         eff_gbps=round(nbytes / dt / 1e9, 2)))
    for n, d, m in [(16384, 512, 1024), (65536, 256, 2048)]:
        A = jax.random.normal(jax.random.PRNGKey(1), (n, d))
        rows_i = jax.random.randint(jax.random.PRNGKey(2), (n,), 0, m)
        signs = jax.random.rademacher(jax.random.PRNGKey(3), (n,),
                                      dtype=A.dtype)
        fn = jax.jit(lambda A, r, s: sjlt_apply(A, r, s, m,
                                                use_pallas=False))
        dt = _time(fn, A, rows_i, signs)
        rows.append(dict(bench="sjlt_ref", n=n, d=d, m=m,
                         us_per_call=round(dt * 1e6, 1),
                         eff_gbps=round(n * d * 4 / dt / 1e9, 2)))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
