"""Paper Figures 4–9 surrogate: 'real dataset' shaped problems.

The container is offline, so CIFAR-100 / SVHN / Dilbert / Guillermo /
OVA-Lung / WESAD cannot be downloaded. The paper's qualitative claims are
spectrum-driven, so we reproduce each dataset's (n, d, c) and a matched
spectral profile (power-law + noise floor, typical of image/RF-feature
Gram spectra) and run the same solver comparison. This is stated in
EXPERIMENTS.md — iteration counts and sketch sizes are comparable;
absolute CPU seconds are not (64-core node in the paper vs 1 core here).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    direct_solve,
    effective_dimension,
    from_least_squares,
)
from .common import emit, timed

# (name, n, d, c) scaled ~1/8 in n,d to fit the 1-core budget; spectra:
# power-law exponent fit to typical image-feature Gram decay.
DATASETS = [
    ("cifar100-like", 7500, 768, 10, 1.2),
    ("svhn-like", 12288, 768, 10, 1.0),
    ("dilbert-like", 2500, 500, 5, 0.8),
    ("guillermo-like", 5000, 1074, 2, 1.0),
    ("ova-lung-like", 1545, 1367, 2, 0.6),   # n < d ⇒ dual regime
    ("wesad-like", 16384, 1250, 2, 1.4),     # RFF features
]


def powerlaw_problem(name, n, d, c, alpha, nu, seed=0):
    key = jax.random.PRNGKey(seed)
    kU, kV, ky = jax.random.split(key, 3)
    r = min(n, d)
    sv = (jnp.arange(1, r + 1, dtype=jnp.float32) ** (-alpha))
    sv = sv / sv[0] + 1e-4
    U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, r)))
    V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, r)))
    A = (U * sv[None, :]) @ V.T
    Y = jax.random.normal(ky, (n, c))
    return from_least_squares(A, Y, nu), sv


def run(nu=1e-2):
    rows = []
    for name, n, d, c, alpha in DATASETS:
        q, sv = powerlaw_problem(name, n, d, c, alpha, nu)
        d_e = float(effective_dimension(sv, nu))
        x_star, t_direct = timed(direct_solve, q)
        err = lambda x: float(jnp.linalg.norm(x - x_star) /
                              jnp.linalg.norm(x_star))
        (x_cg, _), t_cg = timed(cg_solve, q, jnp.zeros_like(q.b), 300)
        res, t_ada = timed(
            lambda: adaptive_solve(
                q, AdaptiveConfig(method="pcg", sketch="sjlt",
                                  max_iters=150, tol=1e-8),
                key=jax.random.PRNGKey(1),
            )
        )
        rows.append(dict(
            fig="fig4-9", dataset=name, n=n, d=d, c=c, d_e=round(d_e),
            direct_s=round(t_direct, 3), cg_s=round(t_cg, 3),
            cg_err=f"{err(x_cg):.2e}", ada_s=round(t_ada, 3),
            ada_iters=res.iters, ada_m=res.m_final,
            ada_err=f"{err(res.x):.2e}",
            ada_faster_than_direct=t_ada < t_direct,
        ))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
