"""Benchmark entry point: one module per paper table/figure, plus the
system benchmarks (batched engine, sketch→Gram pass).

    PYTHONPATH=src python -m benchmarks.run [--only fig1,batched,...]
                                            [--fast] [--json]

Prints CSV-ish rows (``k=v,...``) per benchmark; ``--json`` additionally
writes ``BENCH_solver.json`` — the machine-readable perf-trajectory
baseline (batched-engine + sketch-pass timings with shape/seed metadata)
that CI uploads as an artifact. New rows are MERGED into an existing
``BENCH_solver.json`` keyed by their identifying fields (bench, method,
sketch, shape, dtype, …): a ``--only guard`` run refreshes the guard rows
and keeps everything else, so the artifact preserves the full trajectory
instead of being truncated to the last selection. See each module's
docstring for the reproduction target it validates.
"""

from __future__ import annotations

import argparse
import json
import platform
import sys
import time

BENCH_JSON = "BENCH_solver.json"

# Fields that IDENTIFY a row (what was measured, on which shape, at which
# precision) as opposed to the measurement itself (timings, ratios, bytes,
# agreement flags). Two rows with the same identity are the same benchmark
# point — the newer one replaces the older on merge.
_ID_FIELDS = ("bench", "method", "sketch", "family", "kind", "impl",
              "dtype", "compute_dtype", "B", "n", "d", "m", "m_max", "P",
              "devices", "K", "shards", "seed", "nu", "guards")


def _row_key(row: dict) -> tuple:
    return tuple((k, repr(row[k])) for k in _ID_FIELDS if k in row)


def merge_rows(existing: list[dict], new: list[dict]) -> list[dict]:
    """Merge keyed benchmark rows: a new row replaces the existing row with
    the same identity (in place, preserving trajectory order); genuinely
    new points append. Rows from benches not re-run survive untouched."""
    out = list(existing)
    index = {_row_key(r): i for i, r in enumerate(out)}
    for r in new:
        k = _row_key(r)
        if k in index:
            out[index[k]] = r
        else:
            index[k] = len(out)
            out.append(r)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig1,table1,table2,table3,fig4,"
                         "kernels,batched,sketch_gram,sharded,newton,guard,"
                         "resume,path")
    ap.add_argument("--fast", action="store_true",
                    help="smaller grids (CI-scale)")
    ap.add_argument("--json", action="store_true",
                    help=f"write row-returning benchmarks to {BENCH_JSON}")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_batched, bench_guard, bench_newton, bench_path,
                   bench_resume, bench_sharded, bench_sketch_gram,
                   fig1_synthetic, fig4_realistic, kernels_bench,
                   table1_mdelta, table2_complexity, table3_polyak)

    jobs = {
        "fig1": lambda: fig1_synthetic.run(
            n=2048 if args.fast else 8192, d=256 if args.fast else 1024,
            nus=(1e-1, 1e-2) if args.fast else (1e-1, 1e-2, 1e-3),
        ),
        "table1": lambda: table1_mdelta.run(
            n=1024 if args.fast else 4096, d=128 if args.fast else 512,
        ),
        "table2": lambda: table2_complexity.run(
            n=2048 if args.fast else 8192, d=256 if args.fast else 1024,
        ),
        "table3": table3_polyak.run,
        "fig4": fig4_realistic.run,
        "kernels": kernels_bench.run,
        "batched": lambda: bench_batched.run(
            B=8 if args.fast else 32, n=256 if args.fast else 512,
            d=32 if args.fast else 64, m_max=64 if args.fast else 128,
            reps=1 if args.fast else 3,
        ),
        "sketch_gram": lambda: bench_sketch_gram.run(
            B=2 if args.fast else 4, d=64 if args.fast else 128,
            m_max=128 if args.fast else 512,
            ns=(1024, 2048) if args.fast else (2048, 8192),
            reps=1 if args.fast else 3,
        ),
        "newton": lambda: bench_newton.run(
            B=4 if args.fast else 8, n=512 if args.fast else 2048,
            d=24 if args.fast else 64, m_max=48 if args.fast else 128,
            reps=1 if args.fast else 3,
        ),
        "guard": lambda: bench_guard.run(
            B=8 if args.fast else 32, n=256 if args.fast else 512,
            d=32 if args.fast else 64, m_max=64 if args.fast else 128,
            reps=5 if args.fast else 10,
        ),
        "resume": lambda: bench_resume.run(
            B=8 if args.fast else 32, n=256 if args.fast else 512,
            d=32 if args.fast else 64, m_max=64 if args.fast else 128,
            reps=5 if args.fast else 10,
        ),
        "path": lambda: bench_path.run(
            B=4, n=8192 if args.fast else 16384, d=32, m_max=64, P=16,
            reps=1 if args.fast else 3,
        ),
        "sharded": lambda: bench_sharded.run(
            B=2 if args.fast else 4, n=1024 if args.fast else 4096,
            d=32 if args.fast else 64, m_max=64 if args.fast else 128,
            devices=(1, 4) if args.fast else (1, 2, 4, 8),
            reps=1 if args.fast else 3,
        ),
    }
    t_all = time.time()
    failures = []
    json_rows: list[dict] = []
    for name, fn in jobs.items():
        if only and name not in only:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            rows = fn()
            if args.json and isinstance(rows, list) and all(
                    isinstance(r, dict) for r in rows):
                json_rows.extend(rows)
        except Exception as e:  # keep the harness going, report at the end
            failures.append((name, repr(e)))
            print(f"bench={name},status=ERROR,err={e!r}", flush=True)
        print(f"bench={name},elapsed_s={time.time()-t0:.1f}", flush=True)
    print(f"\ntotal_elapsed_s={time.time()-t_all:.1f}")
    if args.json:
        import os

        import jax

        prior: list[dict] = []
        if os.path.exists(BENCH_JSON):
            try:
                with open(BENCH_JSON) as f:
                    prior = json.load(f).get("rows", [])
            except (json.JSONDecodeError, OSError) as e:
                print(f"warning: could not merge into {BENCH_JSON} ({e!r}); "
                      f"rewriting from this run only")
        rows = merge_rows(prior, json_rows)
        # invariant status at this commit, next to the perf rows: a perf
        # win that broke one-touch/precision/collective invariants is not
        # a win. Quick static subset (traces + source lints, no execution).
        try:
            from repro.analysis.audit.runner import run_audit

            audit = run_audit(quick=True, run_exec=False).summary()
        except Exception as e:  # the perf artifact survives an audit crash
            audit = {"passed": None, "error": repr(e)}
        payload = {
            "meta": {
                "fast": args.fast,
                "backend": jax.default_backend(),
                "jax": jax.__version__,
                "python": platform.python_version(),
                "machine": platform.machine(),
                "elapsed_s": round(time.time() - t_all, 1),
                "audit": audit,
            },
            "rows": rows,
        }
        with open(BENCH_JSON, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"wrote {BENCH_JSON} ({len(json_rows)} new rows, "
              f"{len(rows)} total after merge)")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
