"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig1,table1,...] [--fast]

Prints CSV-ish rows (``k=v,...``) per benchmark; see each module's
docstring for the reproduction target it validates.
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="",
                    help="comma list: fig1,table1,table2,table3,fig4,kernels")
    ap.add_argument("--fast", action="store_true",
                    help="smaller grids (CI-scale)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (fig1_synthetic, fig4_realistic, kernels_bench,
                   table1_mdelta, table2_complexity, table3_polyak)

    jobs = {
        "fig1": lambda: fig1_synthetic.run(
            n=2048 if args.fast else 8192, d=256 if args.fast else 1024,
            nus=(1e-1, 1e-2) if args.fast else (1e-1, 1e-2, 1e-3),
        ),
        "table1": lambda: table1_mdelta.run(
            n=1024 if args.fast else 4096, d=128 if args.fast else 512,
        ),
        "table2": lambda: table2_complexity.run(
            n=2048 if args.fast else 8192, d=256 if args.fast else 1024,
        ),
        "table3": table3_polyak.run,
        "fig4": fig4_realistic.run,
        "kernels": kernels_bench.run,
    }
    t_all = time.time()
    failures = []
    for name, fn in jobs.items():
        if only and name not in only:
            continue
        print(f"\n=== {name} ===", flush=True)
        t0 = time.time()
        try:
            fn()
        except Exception as e:  # keep the harness going, report at the end
            failures.append((name, repr(e)))
            print(f"bench={name},status=ERROR,err={e!r}", flush=True)
        print(f"bench={name},elapsed_s={time.time()-t0:.1f}", flush=True)
    print(f"\ntotal_elapsed_s={time.time()-t_all:.1f}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
