"""Paper Figure 1–3 reproduction: synthetic exponential-decay ridge
problems, relative error vs iteration and vs CPU time, adaptive sketch-size
trajectory, across ν (⇒ d_e) and solvers.

Solvers (as in §6): Direct (Cholesky), CG, PCG(m=2d) [SJLT+SRHT],
Adaptive IHS, Adaptive PCG [SJLT+SRHT].

Default grid is scaled for the 1-core container (n=8192, d=1024); --full
restores the paper's n=16384, d=7000. Outputs CSV rows; the qualitative
reproduction targets are (i) adaptive m_final ≪ 2d and growing as ν ↓,
(ii) adaptive PCG fastest-or-tied in time on the ill-conditioned cells,
(iii) CG degrading as ν ↓ while PCG variants don't.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    direct_solve,
    effective_dimension,
    factorize,
    make_sketch,
    run_fixed,
)
from .common import emit, synthetic_problem, timed


def run(n=8192, d=1024, nus=(1e-1, 1e-2, 1e-3), tol=1e-8, seed=0):
    # Regime preservation: the paper uses σ_j = 0.995^j at d = 7000, where
    # d_e/d ≈ 0.03–0.25. At a scaled d the same decay leaves d_e ≈ d (no
    # room for sketching wins — a parameterization artifact, not physics),
    # so we scale the decay to keep the spectral profile: 0.995^(7000/d).
    decay = 0.995 ** (7000.0 / d)
    rows = []
    for nu in nus:
        q, sv = synthetic_problem(n, d, nu, seed=seed, decay=decay)
        d_e = float(effective_dimension(sv, nu))
        x_star, t_direct = timed(direct_solve, q)
        err = lambda x: float(
            jnp.linalg.norm(x - x_star) / jnp.linalg.norm(x_star)
        )

        # CG
        (x_cg, tr), t_cg = timed(cg_solve, q, jnp.zeros((d,)), 400)
        rows.append(dict(fig="fig1", solver="direct", nu=nu, d_e=round(d_e),
                         time_s=round(t_direct, 3), iters=1, m=0, err=0.0))
        rows.append(dict(fig="fig1", solver="cg", nu=nu, d_e=round(d_e),
                         time_s=round(t_cg, 3), iters=400, m=0,
                         err=err(x_cg)))

        # PCG m=2d (oblivious default)
        for kind in ["sjlt", "srht"]:
            def _pcg2d():
                sk = make_sketch(kind, 2 * d, q.n, jax.random.PRNGKey(7))
                P = factorize(sk.apply(q.A), q.nu, q.lam_diag)
                x, _ = run_fixed(q, P, jnp.zeros((d,)), method="pcg",
                                 iters=25, rho=0.5)
                return x
            x_p, t_p = timed(_pcg2d)
            rows.append(dict(fig="fig1", solver=f"pcg2d-{kind}", nu=nu,
                             d_e=round(d_e), time_s=round(t_p, 3), iters=25,
                             m=2 * d, err=err(x_p)))

        # adaptive IHS / PCG
        for method in ["ihs", "pcg"]:
            for kind in ["sjlt", "srht"]:
                def _ada():
                    return adaptive_solve(
                        q, AdaptiveConfig(method=method, sketch=kind,
                                          max_iters=200, tol=tol),
                        key=jax.random.PRNGKey(1),
                    )
                res, t_a = timed(_ada)
                rows.append(dict(
                    fig="fig1", solver=f"ada-{method}-{kind}", nu=nu,
                    d_e=round(d_e), time_s=round(t_a, 3), iters=res.iters,
                    m=res.m_final, err=err(res.x),
                ))
    for r in rows:
        emit(r)
    return rows


if __name__ == "__main__":
    run()
