"""Guard-overhead benchmark: the failure-isolation layer vs the pre-guard
hot path (DESIGN.md §9).

The engine's guards (post-Cholesky level-validity remap, finiteness-checked
iterate proposals, status bookkeeping) run INSIDE the jitted while_loop, so
they must be close to free or the failure model taxes every healthy solve.
This benchmark times ``padded_adaptive_solve_batched`` with ``guards=True``
(the default every production path uses) against ``guards=False`` (the
pre-guard graph) on the ``bench_batched.py`` heterogeneous shapes, and
asserts bit-identical iterates between the two on clean traffic — the
overhead being measured buys bookkeeping, never a different answer.

Budget: ≤ 3% overhead (``overhead_pct`` in the emitted rows; the row also
records the bitwise agreement so a regression in EITHER dimension is
visible in BENCH_solver.json).

    PYTHONPATH=src python benchmarks/bench_guard.py [--B 32] [--reps 3]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks.bench_batched import heterogeneous_batch, time_best
from benchmarks.common import emit
from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.quadratic import from_least_squares_batch
from repro.core.status import status_name

# IHS needs a larger sketch cap than PCG on the same problem: its fixed
# 1−ρ step is only a contraction while m comfortably exceeds the effective
# dimension (≈ 4·d_e for ρ = 1/2, Thm 3.2), whereas PCG converges — just
# more slowly — under any SPD preconditioner. At the shared m_max = 2·d a
# minority of the heterogeneous problems (small-ν slots with d_e ≈ d) hit
# the ladder cap below that multiple and stall honestly; the bench's IHS
# leg therefore gets a 4× budget so every slot reaches OK and the row
# measures guard overhead on clean traffic, not cap-starved IHS.
_IHS_M_MAX_FACTOR = 4


def run(B: int = 32, n: int = 512, d: int = 64, m_max: int = 128,
        reps: int = 10, tol: float = 1e-12, seed: int = 42) -> list[dict]:
    """Emit + return one row per (method, sketch) combination.

    ``reps`` defaults higher than the other benches: the quantity being
    resolved is a few-percent *difference* between two ~0.1 s solves, so
    best-of-3 is dominated by scheduler noise — best-of-10 per side is
    what makes the ≤3% budget a measurable claim."""
    A, Y, nus = heterogeneous_batch(B, n, d)
    qb = from_least_squares_batch(A, Y, nus)
    keys = jax.random.split(jax.random.PRNGKey(seed), B)

    rows = []
    for method, sketch in [("pcg", "gaussian"), ("pcg", "sjlt"),
                           ("pcg", "srht"), ("ihs", "gaussian")]:
        mm = m_max * (_IHS_M_MAX_FACTOR if method == "ihs" else 1)
        solve = lambda guards: padded_adaptive_solve_batched(
            qb, keys, m_max=mm, method=method, sketch=sketch,
            max_iters=200, rho=0.5, tol=tol, guards=guards)

        xg, sg = jax.block_until_ready(solve(True))     # warm + correctness
        xn, sn = jax.block_until_ready(solve(False))
        bitwise = bool(jnp.all(xg == xn)) and bool(
            jnp.all(sg["dtilde"] == sn["dtilde"]))

        t_guarded = time_best(lambda: solve(True)[0], reps)
        t_unguarded = time_best(lambda: solve(False)[0], reps)
        overhead = 100.0 * (t_guarded - t_unguarded) / t_unguarded

        # per-status histogram: how each of the B slots actually ended —
        # a single boolean hid WHICH lattice verdict non-OK slots got
        codes, counts = jnp.unique(sg["status"], return_counts=True)
        status_hist = {status_name(int(c)): int(k)
                       for c, k in zip(codes, counts)}
        row = {
            "bench": "guard_overhead", "method": method, "sketch": sketch,
            "B": B, "n": n, "d": d, "m_max": mm, "seed": seed,
            "guarded_s": round(t_guarded, 4),
            "unguarded_s": round(t_unguarded, 4),
            "overhead_pct": round(overhead, 2),
            "bitwise_agreement": bitwise,
            "status_hist": status_hist,
        }
        emit(row)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=32)
    ap.add_argument("--n", type=int, default=512)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=128)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=1e-12)
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max, reps=args.reps,
        tol=args.tol)


if __name__ == "__main__":
    main()
