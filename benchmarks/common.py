"""Shared benchmark utilities: synthetic problems matching the paper's §6
setup (exponential spectral decay σ_j = 0.995^j), timing helpers, CSV out.

The container is 1-core CPU; the paper's grid (n up to 524288, d up to
14000) is reproduced at reduced scale by default, with ``--full`` restoring
the paper's dimensions (hours on this box). Wall-times are reported next to
iteration/FLOP counts — the scale-free comparisons (iterations, sketch
sizes, flops) are the reproduction targets; CPU seconds are environmental.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import from_least_squares
from repro.core.effective_dim import exp_decay_singular_values


def synthetic_problem(n: int, d: int, nu: float, *, decay: float = 0.995,
                      seed: int = 0, dtype=jnp.float32):
    """Paper §6: A with σ_j = decay^j, dense orthogonal factors."""
    key = jax.random.PRNGKey(seed)
    sv = exp_decay_singular_values(d, decay).astype(dtype)
    kU, kV, ky = jax.random.split(key, 3)
    # economical orthogonal factors: QR of Gaussian blocks
    U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d), dtype=dtype))
    V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d), dtype=dtype))
    A = (U * sv[None, :]) @ V.T
    y = jax.random.normal(ky, (n,), dtype=dtype)
    return from_least_squares(A, y, nu), sv


def timed(fn, *args, **kwargs):
    t0 = time.perf_counter()
    out = fn(*args, **kwargs)
    out = jax.block_until_ready(out)
    return out, time.perf_counter() - t0


def emit(row: dict):
    """CSV-ish one-line record (the harness contract: name,us,derived)."""
    print(",".join(f"{k}={v}" for k, v in row.items()), flush=True)
