"""Regularization-path engine vs a per-λ loop (DESIGN.md §13).

The path question: given B problems × a P-point λ grid, the ladder-level
Grams are λ-free, so ONE one-touch sketch pass should serve the whole
grid — per-λ cost collapses to the ν²Λ-shifted factorizations + a
warm-started solve. This bench measures exactly that collapse:

* ``single_pre_s``  — ONE single-λ precompute (sketch pass + ladder
  factorizations), the unit the grid is budgeted against;
* ``grid_pre_s``    — the ENTIRE grid's precompute in path mode: one
  ``prepare_path_ladder`` pass + P per-λ shifted factorizations off the
  shared ladder. The headline claim is ``grid_pre_s ≤ 2 × single_pre_s``;
* ``path_s`` vs ``loop_s`` — full path solve (warm-started x + level)
  vs a per-λ loop of independent engine calls, each paying its own
  sketch pass (``speedup_vs_loop``, claimed ≥ 6× at CI shape);
* sketch-pass counts (1 vs P) and the traced peak intermediate bytes of
  both programs;
* ``max_rel_err`` — per-λ path solutions vs the independent solves
  (claimed ≤ 1e-5; both sides anchored at the m = d ladder level so the
  comparison isn't polluted by the cold level-0 certificate corner).

    PYTHONPATH=src python benchmarks/bench_path.py [--B 4] [--P 16]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.bench_batched import heterogeneous_batch, time_best
from benchmarks.common import emit
from repro.core.adaptive_padded import (
    doubling_ladder,
    padded_adaptive_solve_batched,
    padded_path_solve_batched,
    prepare_padded_solve,
    prepare_path_ladder,
)
from repro.core.precond import shifted_ladder_inverses
from repro.core.quadratic import from_least_squares_batch


def _peak_bytes(fn, *args) -> int:
    from repro.analysis.audit import jaxpr_utils as ju

    return ju.max_intermediate_bytes(jax.make_jaxpr(fn)(*args))[0]


def run(B: int = 4, n: int = 16384, d: int = 32, m_max: int = 64,
        P: int = 16, reps: int = 3, tol: float = 1e-12, nu_min: float = 0.05,
        seed: int = 42, sketch: str = "gaussian") -> list[dict]:
    """Emit + return one row for the path engine at this shape.

    ``nu_min`` floors the grid at λmin(H) = ν²: the ≤1e-5 agreement claim
    compares two independently-converged δ̃ ≈ 1e-12 solves, whose x-space
    gap scales like √(δ̃/ν²) — an ill-conditioning amplification, not a
    path-engine error."""
    A, Y, _ = heterogeneous_batch(B, n, d)
    nus = jnp.asarray(np.geomspace(1.0, nu_min, P), jnp.float32)
    qb = from_least_squares_batch(A, Y, jnp.full((B,), 1.0, jnp.float32))
    keys = jax.random.split(jax.random.PRNGKey(seed), B)
    # anchor both sides at the m = d level: below it H_S ≈ ν²Λ and the
    # cold δ̃(0) scale is inflated (the level-0 certificate corner)
    ladder = doubling_ladder(m_max)
    lvl0 = jnp.full((B,), ladder.index(min(d, m_max)), jnp.int32)

    import dataclasses

    def q_at(nu):
        return dataclasses.replace(
            qb, nu=jnp.full((B,), nu, qb.b.dtype))

    path = lambda: padded_path_solve_batched(
        qb, keys, nus, m_max=m_max, method="pcg", sketch=sketch,
        max_iters=200, rho=0.5, tol=tol, init_level=lvl0)
    loop_one = lambda nu: padded_adaptive_solve_batched(
        q_at(nu), keys, m_max=m_max, method="pcg", sketch=sketch,
        max_iters=200, rho=0.5, tol=tol, init_level=lvl0)
    loop = lambda: [loop_one(float(nu))[0] for nu in nus]

    # -- precompute budget: the WHOLE grid vs one single-λ precompute ------
    single_pre = lambda: prepare_padded_solve(
        q_at(1.0), keys, m_max=m_max, sketch=sketch)[0].pinvs

    @jax.jit
    def all_inverses(grams, nus, lam):
        # all P shifted factorizations off the ONE shared ladder, in one
        # dispatch — the per-λ cost path mode actually pays
        return jax.vmap(lambda nu: shifted_ladder_inverses(
            grams, jnp.full((B,), nu, grams.dtype), lam))(nus)

    def grid_pre():
        grams, _ = prepare_path_ladder(qb, keys, m_max=m_max, sketch=sketch)
        return all_inverses(grams, nus, qb.lam_diag)

    jax.block_until_ready(single_pre())                      # warm
    jax.block_until_ready(grid_pre())
    t_single_pre = time_best(single_pre, reps)
    t_grid_pre = time_best(grid_pre, reps)

    # -- full solves -------------------------------------------------------
    xs_path, stats = path()                                  # warm + keep
    xs_path = jax.block_until_ready(xs_path)
    xs_loop = jax.block_until_ready(loop())
    t_path = time_best(lambda: path()[0], reps)
    t_loop = time_best(loop, reps)

    rel = 0.0
    for p in range(P):
        num = jnp.linalg.norm(xs_path[p] - xs_loop[p], axis=-1)
        den = jnp.linalg.norm(xs_loop[p], axis=-1)
        rel = max(rel, float(jnp.max(num / den)))

    peak_path = _peak_bytes(
        lambda q, k, nu: padded_path_solve_batched(
            q, k, nu, m_max=m_max, method="pcg", sketch=sketch,
            max_iters=200, tol=tol)[0], qb, keys, nus)
    peak_loop = _peak_bytes(
        lambda q, k, nu: jnp.stack([
            padded_adaptive_solve_batched(
                dataclasses.replace(q, nu=nu[p]), k, m_max=m_max,
                method="pcg", sketch=sketch, max_iters=200, tol=tol)[0]
            for p in range(P)]),
        qb, keys, jnp.broadcast_to(nus[:, None], (P, B)))

    pre_ratio = t_grid_pre / t_single_pre
    speedup = t_loop / t_path
    row = {
        "bench": "path", "method": "pcg", "sketch": sketch,
        "B": B, "n": n, "d": d, "m_max": m_max, "P": P, "seed": seed,
        "single_pre_s": round(t_single_pre, 4),
        "grid_pre_s": round(t_grid_pre, 4),
        "pre_ratio": round(pre_ratio, 2),
        "path_s": round(t_path, 4),
        "loop_s": round(t_loop, 4),
        "speedup_vs_loop": round(speedup, 2),
        "path_sketch_passes": int(stats["sketch_passes"]),
        "loop_sketch_passes": P,
        "path_peak_bytes": int(peak_path),
        "loop_peak_bytes": int(peak_loop),
        "max_rel_err": float(f"{rel:.2e}"),
        "max_dtilde": float(
            f"{float(np.max(np.asarray(stats['dtilde']))):.2e}"),
        "pre_within_2x": bool(pre_ratio <= 2.0),
        "speedup_ge_6x": bool(speedup >= 6.0),
    }
    emit(row)
    return [row]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=4)
    ap.add_argument("--n", type=int, default=16384)
    ap.add_argument("--d", type=int, default=32)
    ap.add_argument("--m-max", type=int, default=64)
    ap.add_argument("--P", type=int, default=16)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--tol", type=float, default=1e-12)
    ap.add_argument("--nu-min", type=float, default=0.05)
    ap.add_argument("--sketch", default="gaussian")
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max, P=args.P,
        reps=args.reps, tol=args.tol, nu_min=args.nu_min,
        sketch=args.sketch)


if __name__ == "__main__":
    main()
