"""GLM sketched-Newton driver vs unpreconditioned Newton-CG (DESIGN.md §8).

The serving question for the GLM layer: given a batch of B logistic-ridge
problems, how much does the adaptively-sketched inner preconditioner buy
over the standard matrix-free baseline (Newton with plain CG inner solves,
the same outer line-searched loop)? Also reports the adaptivity evidence:
the warm-started per-step m trajectory next to the weighted effective
dimension d_e(W) at the solution — the quantity Theorem 5-style bounds say
the adapted m should track (computed by the exact-eigen oracle
``effective_dimension_weighted_exact``; the solver itself never needs it).

Note on theory columns: where d_e(W) is turned into a predicted m via
``m_delta_sjlt``-style Table-1 forms, the leading constant is implicitly 1
(the paper states only the order) — treat any such column as an order-of-
magnitude anchor, not a sharp prediction (see m_delta_sjlt's docstring).

    PYTHONPATH=src python benchmarks/bench_newton.py [--B 8] [--reps 3]

Emits one CSV-ish row per (family, sketch); rows land in BENCH_solver.json
via ``run.py --json --only newton``.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core.effective_dim import effective_dimension_weighted_exact
from repro.core.newton import (
    adaptive_newton_solve_batched,
    newton_cg_reference,
)
from repro.core.objectives import get_objective, glm_grad_and_weights
from repro.core.quadratic import _as_batched_reg


def logistic_batch(B: int, n: int, d: int, seed: int = 0):
    """Shared data law (``objectives.synthetic_logistic_batch``), at
    scale 1.5 so the margins saturate and the Hessian weights vary across
    rows — the thing the weighted sketch has to get right."""
    from repro.core.objectives import synthetic_logistic_batch

    return synthetic_logistic_batch(jax.random.PRNGKey(seed), B, n, d,
                                    scale=1.5)


def time_best(fn, reps: int) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def run(B: int = 8, n: int = 2048, d: int = 64, m_max: int = 128,
        reps: int = 3, nu: float = 0.3, seed: int = 7) -> list[dict]:
    A, Y = logistic_batch(B, n, d, seed=seed)
    keys = jax.random.PRNGKey(seed)
    rows = []
    for family, sketch in [("logistic", "gaussian"), ("logistic", "sjlt")]:
        solve = lambda: adaptive_newton_solve_batched(
            family, A, Y, nu, m_max=m_max, sketch=sketch, keys=keys)[0]
        x, stats = adaptive_newton_solve_batched(      # warm + certificates
            family, A, Y, nu, m_max=m_max, sketch=sketch, keys=keys)
        t_newton = time_best(solve, reps)

        cg = lambda: newton_cg_reference(family, A, Y, nu, cg_iters=200)
        x_cg = jax.block_until_ready(cg())             # warm-up IS the result
        t_cg = time_best(cg, reps)
        rel = float(jnp.max(jnp.linalg.norm(x - x_cg, axis=1)
                            / (jnp.linalg.norm(x_cg, axis=1) + 1e-30)))

        # weighted effective dimension at the solution, per problem
        obj = get_objective(family)
        nu_b, lam_b = _as_batched_reg(nu, None, B, d, A.dtype)
        _, w = glm_grad_and_weights(obj, A, Y, nu_b, lam_b, x)
        d_e = [effective_dimension_weighted_exact(A[i], w[i], nu)
               for i in range(B)]
        mf = np.asarray(stats["m_final"])
        outer = np.asarray(stats["newton_iters"])
        traj0 = stats["m_trajectory"][:, 0]
        row = {
            "bench": "newton_glm", "family": family, "sketch": sketch,
            "B": B, "n": n, "d": d, "m_max": m_max, "nu": nu, "seed": seed,
            "newton_s": round(t_newton, 4),
            "newton_cg_s": round(t_cg, 4),
            "speedup_vs_newton_cg": round(t_cg / t_newton, 2),
            "max_rel_err_vs_cg": float(f"{rel:.2e}"),
            "outer_iters_max": int(outer.max()),
            "m_final_min": int(mf.min()), "m_final_max": int(mf.max()),
            "m_traj_p0": "/".join(str(int(m)) for m in traj0 if m > 0),
            "d_e_w_min": round(min(d_e), 1),
            "d_e_w_max": round(max(d_e), 1),
            "max_decrement": float(
                f"{float(jnp.max(stats['decrement'])):.2e}"),
            "all_converged": bool(np.all(np.asarray(stats["converged"]))),
        }
        emit(row)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=8)
    ap.add_argument("--n", type=int, default=2048)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=128)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max, reps=args.reps)


if __name__ == "__main__":
    main()
