"""Sharded one-touch sketch pass + padded adaptive solve vs device count.

Measures, for each data-shard count K, the wall time of (a) the sharded
ladder precompute (``shard_level_grams``: per-shard one-touch pass + ONE
psum of the (L, B, d, d) level Grams) and (b) the full sharded
``padded_adaptive_solve_batched`` — against the K=1 single-device engine
with the ``BlockEmulationProvider`` reference (identical math, no mesh).

Each K runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=K``: forced host
devices time-slice one CPU, so K>1 wall times measure the *overhead* of
the sharded program (collective + partitioning cost), not a speedup — the
point on this box is that the overhead stays small and the collective
inventory is exactly one psum(L·B·d²) in the precompute (asserted by
tests/test_sharded.py); on a real multi-chip mesh the same program shards
the O(n) sketch pass K ways. Rows land in ``BENCH_solver.json`` via
``benchmarks/run.py --json``.

    PYTHONPATH=src python -m benchmarks.bench_sharded [--devices 1,2,4,8]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap

from benchmarks.common import emit

_CHILD = """
    import json, time
    import jax, jax.numpy as jnp, numpy as np
    from repro.core.adaptive_padded import (doubling_ladder,
                                            padded_adaptive_solve_batched)
    from repro.core.distributed import shard_level_grams, shard_quadratic
    from repro.core.level_grams import BlockEmulationProvider, get_provider
    from repro.core.quadratic import from_least_squares_batch

    cfg = json.loads({cfg!r})
    B, n, d, m_max = cfg["B"], cfg["n"], cfg["d"], cfg["m_max"]
    K, sketch, reps, seed = cfg["K"], cfg["sketch"], cfg["reps"], cfg["seed"]

    A = jax.random.normal(jax.random.PRNGKey(seed), (B, n, d)) / np.sqrt(n)
    Y = jax.random.normal(jax.random.PRNGKey(seed + 1), (B, n))
    nus = 0.1 + 0.1 * jnp.arange(B, dtype=jnp.float32) / max(B - 1, 1)
    q = from_least_squares_batch(A, Y, nus)
    keys = jax.random.split(jax.random.PRNGKey(seed + 2), B)
    ladder = doubling_ladder(m_max)

    def best_of(fn, *args):
        jax.block_until_ready(fn(*args))          # compile + warm
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(*args))
            best = min(best, time.perf_counter() - t0)
        return best

    if K == 1:
        # single-device baseline: identical concatenated-block math via the
        # emulation provider (K_emu shards of the largest mesh in the sweep)
        prov = BlockEmulationProvider(sketch, cfg["K_emu"])
        pass_fn = jax.jit(lambda q, ks: prov.level_grams(
            prov.sample(ks, m_max, q.n, q.A.dtype), q, ladder))
        solve_fn = lambda q, ks: padded_adaptive_solve_batched(
            q, ks, m_max=m_max, method="pcg", sketch=prov, tol=1e-8,
            max_iters=100)
        qd = q
    else:
        mesh = jax.make_mesh((K,), ("data",))
        prov = get_provider(sketch)
        qd = shard_quadratic(q, mesh)
        pass_fn = jax.jit(lambda q, ks: shard_level_grams(
            prov, ks, q, ladder, mesh), static_argnames=())
        solve_fn = lambda q, ks: padded_adaptive_solve_batched(
            q, ks, m_max=m_max, method="pcg", sketch=sketch, tol=1e-8,
            max_iters=100, mesh=mesh)

    sketch_pass_s = best_of(pass_fn, qd, keys)
    solve_s = best_of(lambda q, ks: solve_fn(q, ks)[0], qd, keys)
    x, stats = solve_fn(qd, keys)
    print("ROW " + json.dumps({{
        "bench": "sharded", "sketch": sketch, "devices": K,
        "B": B, "n": n, "d": d, "m_max": m_max, "seed": seed,
        "sketch_pass_s": round(sketch_pass_s, 4),
        "solve_s": round(solve_s, 4),
        "m_final_max": int(np.asarray(stats["m_final"]).max()),
        "dtilde_max": float(np.asarray(stats["dtilde"]).max()),
    }}))
"""


def _run_child(cfg: dict) -> dict:
    env = {**os.environ,
           "XLA_FLAGS": f"--xla_force_host_platform_device_count={cfg['K']}",
           "PYTHONPATH": "src" + (
               os.pathsep + os.environ["PYTHONPATH"]
               if os.environ.get("PYTHONPATH") else "")}
    code = textwrap.dedent(_CHILD).format(cfg=json.dumps(cfg))
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=900)
    if r.returncode != 0:
        raise RuntimeError(f"K={cfg['K']} child failed:\n{r.stderr[-3000:]}")
    for line in r.stdout.splitlines():
        if line.startswith("ROW "):
            return json.loads(line[4:])
    raise RuntimeError(f"K={cfg['K']} child printed no ROW:\n{r.stdout}")


def run(B: int = 4, n: int = 4096, d: int = 64, m_max: int = 128,
        devices: tuple[int, ...] = (1, 2, 4, 8), sketch: str = "gaussian",
        reps: int = 3, seed: int = 0) -> list[dict]:
    rows = []
    k_emu = max(devices)
    for k in devices:
        row = _run_child({"B": B, "n": n, "d": d, "m_max": m_max, "K": k,
                          "K_emu": k_emu, "sketch": sketch, "reps": reps,
                          "seed": seed})
        emit(row)
        rows.append(row)
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--B", type=int, default=4)
    ap.add_argument("--n", type=int, default=4096)
    ap.add_argument("--d", type=int, default=64)
    ap.add_argument("--m-max", type=int, default=128)
    ap.add_argument("--devices", default="1,2,4,8")
    ap.add_argument("--sketch", default="gaussian")
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args()
    run(B=args.B, n=args.n, d=args.d, m_max=args.m_max,
        devices=tuple(int(x) for x in args.devices.split(",")),
        sketch=args.sketch, reps=args.reps)


if __name__ == "__main__":
    main()
