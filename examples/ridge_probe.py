"""The paper's solver as a *framework feature*: distributed ridge-probe
head fitting on frozen backbone features.

Extract hidden-state features from a (reduced) qwen2 backbone over a token
stream, then fit a multi-class linear readout by ridge regression with the
adaptive sketching PCG — the row-sharded feature matrix is exactly the
layout activations have under data parallelism (core/distributed.py).

    PYTHONPATH=src python examples/ridge_probe.py
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    direct_solve,
    from_least_squares,
)
from repro.models import init_params
from repro.models import transformer as T
from repro.models import layers as L


def backbone_features(params, cfg, tokens):
    """Final-norm hidden states (B, S, D) — the probe's input features."""
    x = T.embed_tokens(params, cfg, tokens, jnp.float32)
    positions = jnp.arange(tokens.shape[1])
    for i, kind in enumerate(cfg.pattern):
        name = f"p{i}_{kind}"

        def body(x, xs, kind=kind):
            bp, _ = xs
            x, _ = T.apply_layer(bp, cfg, kind, x, positions)
            return x, None

        x, _ = jax.lax.scan(body, x, (params["blocks"][name], None))
    return L.rms_norm(params["final_norm"], x, cfg.norm_eps)


def main():
    cfg = get_config("qwen2-0.5b").reduced()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=64)
    B, S, classes = 64, 32, 10
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    feats = backbone_features(params, cfg, tokens).reshape(B * S, cfg.d_model)
    print(f"features: {feats.shape} from {cfg.name}")

    # synthetic multi-class targets from a hidden linear map + noise
    W_true = jax.random.normal(jax.random.PRNGKey(2),
                               (cfg.d_model, classes)) / 8
    Y = feats @ W_true + 0.05 * jax.random.normal(
        jax.random.PRNGKey(3), (B * S, classes))

    q = from_least_squares(feats, Y, nu=0.3)
    t0 = time.perf_counter()
    res = adaptive_solve(
        q, AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=100,
                          tol=1e-9),
        key=jax.random.PRNGKey(4),
    )
    t_ada = time.perf_counter() - t0
    W_star = direct_solve(q)
    rel = float(jnp.linalg.norm(res.x - W_star) / jnp.linalg.norm(W_star))
    mse = float(jnp.mean((feats @ res.x - Y) ** 2))
    print(f"adaptive PCG: {t_ada:.2f}s  iters={res.iters} "
          f"m_final={res.m_final}  rel_err_vs_direct={rel:.2e}  mse={mse:.4f}")


if __name__ == "__main__":
    main()
