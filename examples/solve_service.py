"""Ridge-solve serving demo: heterogeneous requests through the shape-class
bucketing + batched multi-problem adaptive engine (DESIGN.md §6), with the
preemptible-solve lifecycle (DESIGN.md §11) on top.

Submits a stream of ridge problems with random shapes and regularization,
flushes them through the service, audits every returned solution against a
dense direct solve, and prints each request's adaptivity certificate —
including which sketch family and sketch-pass compute dtype produced it.

    PYTHONPATH=src python examples/solve_service.py --sketch srht
    PYTHONPATH=src python examples/solve_service.py --dtype bf16

``--deadline-s`` bounds the whole flush: chunks are dispatched earliest-
deadline-first and a spent budget stops a solve BETWEEN segments — expired
requests come back ``DEADLINE_EXCEEDED`` with their best finite iterate:

    PYTHONPATH=src python examples/solve_service.py --deadline-s 2.0

``--checkpoint-dir`` makes every solve preemptible: SIGTERM checkpoints
the in-flight chunk's solver state and exits 75; re-running with
``--resume`` (same request stream — the seeds are fixed) restores the
committed segment and finishes with identical numerics. The launcher's
``python -m repro.launch.serve --preempt-after N`` drives exactly this
kill → restart cycle:

    PYTHONPATH=src python examples/solve_service.py --checkpoint-dir /tmp/ck
    # ... SIGTERM mid-flush → "PREEMPTED at segment k", exit 75 ...
    PYTHONPATH=src python examples/solve_service.py --checkpoint-dir /tmp/ck \\
        --resume
"""

import argparse
import shutil
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PreemptedError, direct_solve, from_least_squares
from repro.core.level_grams import COMPUTE_DTYPES, PADDED_SKETCHES
from repro.serve.solver_service import SolverService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sketch", default="gaussian",
                    choices=PADDED_SKETCHES,
                    help="sketch family for the adaptive engine")
    ap.add_argument("--dtype", default="fp32", choices=COMPUTE_DTYPES,
                    help="sketch-pass compute dtype (DESIGN.md §10): "
                         "bf16/int8 reduce stream precision, certificates "
                         "stay fp32")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--certificates", type=int, default=8,
                    help="how many per-request certificate lines to print")
    ap.add_argument("--tol", type=float, default=1e-12)
    ap.add_argument("--max-iters", type=int, default=100)
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--no-fallback", action="store_true",
                    help="disable the dense direct_solve fallback")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="wall-clock budget for the whole flush; expired "
                         "requests return DEADLINE_EXCEEDED with their "
                         "best finite iterate (DESIGN.md §11)")
    ap.add_argument("--segment-trips", type=int, default=32,
                    help="loop trips per dispatched segment when the solve "
                         "runs preemptibly")
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint in-flight solver state here; SIGTERM "
                         "then exits 75 after committing, and --resume "
                         "continues from the committed segment")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir instead of wiping it")
    args = ap.parse_args(argv)

    preempt = None
    if args.checkpoint_dir:
        if not args.resume:
            shutil.rmtree(args.checkpoint_dir, ignore_errors=True)
        from repro.ft import PreemptionHandler

        preempt = PreemptionHandler(signals=(signal.SIGTERM,))
        preempt.__enter__()

    svc = SolverService(batch_size=16, method="pcg", sketch=args.sketch,
                        compute_dtype=args.dtype, tol=args.tol,
                        max_iters=args.max_iters,
                        max_retries=args.max_retries,
                        fallback=not args.no_fallback,
                        segment_trips=args.segment_trips,
                        checkpoint_dir=args.checkpoint_dir or None,
                        preempt=preempt)
    rng = np.random.default_rng(0)
    requests = {}
    for i in range(args.requests):
        n = int(rng.integers(64, 1500))
        d = int(rng.integers(8, 100))
        A = jax.random.normal(jax.random.PRNGKey(2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (n,))
        nu = float(rng.uniform(0.05, 0.5))
        rid = svc.submit(A, y, nu)
        requests[rid] = (A, y, nu)

    t0 = time.perf_counter()
    try:
        sols = svc.flush(deadline_s=args.deadline_s)
    except PreemptedError as e:
        print(f"PREEMPTED at segment {e.segment} "
              f"(state committed to {e.checkpoint_dir}); "
              f"re-run with --resume to continue", flush=True)
        sys.exit(75)   # EX_TEMPFAIL: restart me
    dt = time.perf_counter() - t0

    counts: dict[str, int] = {}
    for s in sols.values():
        counts[s.status] = counts.get(s.status, 0) + 1
    all_finite = all(bool(jnp.all(jnp.isfinite(s.x))) for s in sols.values())

    ok = {rid: s for rid, s in sols.items() if s.converged}
    worst = 0.0
    for rid, s in ok.items():
        A, y, nu = requests[rid]
        x_star = direct_solve(from_least_squares(A, y, nu))
        rel = float(jnp.linalg.norm(s.x - x_star) / jnp.linalg.norm(x_star))
        worst = max(worst, rel)

    print(f"{len(requests)} requests in {dt:.2f}s "
          f"(incl. compile; {svc.stats['batches']} batches, "
          f"{svc.stats['padded_slots']} padded slots)")
    print("statuses: "
          + ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
          + f"; segments={svc.stats['segments']}, "
            f"resumed_chunks={svc.stats['resumed_chunks']}, "
            f"deadline_exceeded={svc.stats['deadline_exceeded']}")
    print(f"ALL_FINITE={int(all_finite)}")
    if ok:
        m_finals = sorted(s.m_final for s in ok.values())
        print(f"worst relative error vs direct solve: {worst:.2e}")
        print(f"adapted sketch sizes m_final: min={m_finals[0]} "
              f"median={m_finals[len(m_finals) // 2]} max={m_finals[-1]}")
    for rid in sorted(ok)[: args.certificates]:
        s = ok[rid]
        print(f"  cert req={rid:3d} sketch={s.sketch:<14s} "
              f"dtype={s.compute_dtype:<4s} "
              f"class=(n={s.shape_class.n}, d={s.shape_class.d}, "
              f"m_max={s.shape_class.m_max}) m_final={s.m_final:4d} "
              f"iters={s.iters:3d} doublings={s.doublings} "
              f"δ̃={s.delta_tilde:.2e}")


if __name__ == "__main__":
    main()
