"""Ridge-solve serving demo: heterogeneous requests through the shape-class
bucketing + batched multi-problem adaptive engine (DESIGN.md §6).

Submits a stream of ridge problems with random shapes and regularization,
flushes them through the service, audits every returned solution against a
dense direct solve, and prints each request's adaptivity certificate —
including which sketch family and sketch-pass compute dtype produced it.

    PYTHONPATH=src python examples/solve_service.py --sketch srht
    PYTHONPATH=src python examples/solve_service.py --dtype bf16
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import direct_solve, from_least_squares
from repro.core.level_grams import COMPUTE_DTYPES, PADDED_SKETCHES
from repro.serve.solver_service import SolverService


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--sketch", default="gaussian",
                    choices=PADDED_SKETCHES,
                    help="sketch family for the adaptive engine")
    ap.add_argument("--dtype", default="fp32", choices=COMPUTE_DTYPES,
                    help="sketch-pass compute dtype (DESIGN.md §10): "
                         "bf16/int8 reduce stream precision, certificates "
                         "stay fp32")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--certificates", type=int, default=8,
                    help="how many per-request certificate lines to print")
    args = ap.parse_args()

    svc = SolverService(batch_size=16, method="pcg", sketch=args.sketch,
                        compute_dtype=args.dtype, tol=1e-12)
    rng = np.random.default_rng(0)
    requests = {}
    for i in range(args.requests):
        n = int(rng.integers(64, 1500))
        d = int(rng.integers(8, 100))
        A = jax.random.normal(jax.random.PRNGKey(2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (n,))
        nu = float(rng.uniform(0.05, 0.5))
        rid = svc.submit(A, y, nu)
        requests[rid] = (A, y, nu)

    t0 = time.perf_counter()
    sols = svc.flush()
    dt = time.perf_counter() - t0

    worst = 0.0
    for rid, (A, y, nu) in requests.items():
        s = sols[rid]
        x_star = direct_solve(from_least_squares(A, y, nu))
        rel = float(jnp.linalg.norm(s.x - x_star) / jnp.linalg.norm(x_star))
        worst = max(worst, rel)
    m_finals = sorted(s.m_final for s in sols.values())

    print(f"{len(requests)} requests in {dt:.2f}s "
          f"(incl. compile; {svc.stats['batches']} batches, "
          f"{svc.stats['padded_slots']} padded slots)")
    print(f"worst relative error vs direct solve: {worst:.2e}")
    print(f"adapted sketch sizes m_final: min={m_finals[0]} "
          f"median={m_finals[len(m_finals) // 2]} max={m_finals[-1]}")
    for rid in sorted(sols)[: args.certificates]:
        s = sols[rid]
        print(f"  cert req={rid:3d} sketch={s.sketch:<14s} "
              f"dtype={s.compute_dtype:<4s} "
              f"class=(n={s.shape_class.n}, d={s.shape_class.d}, "
              f"m_max={s.shape_class.m_max}) m_final={s.m_final:4d} "
              f"iters={s.iters:3d} doublings={s.doublings} "
              f"δ̃={s.delta_tilde:.2e}")


if __name__ == "__main__":
    main()
