"""Ridge-solve serving demo: heterogeneous requests through the shape-class
bucketing + batched multi-problem adaptive engine (DESIGN.md §6), with the
preemptible-solve lifecycle (DESIGN.md §11) on top.

Submits a stream of ridge problems with random shapes and regularization,
flushes them through the service, audits every returned solution against a
dense direct solve, and prints each request's adaptivity certificate —
including which sketch family and sketch-pass compute dtype produced it.

    PYTHONPATH=src python examples/solve_service.py --sketch srht
    PYTHONPATH=src python examples/solve_service.py --dtype bf16

``--deadline-s`` bounds the whole flush: chunks are dispatched earliest-
deadline-first and a spent budget stops a solve BETWEEN segments — expired
requests come back ``DEADLINE_EXCEEDED`` with their best finite iterate:

    PYTHONPATH=src python examples/solve_service.py --deadline-s 2.0

``--checkpoint-dir`` makes every solve preemptible: SIGTERM checkpoints
the in-flight chunk's solver state and exits 75; re-running with
``--resume`` (same request stream — the seeds are fixed) restores the
committed segment and finishes with identical numerics. The launcher's
``python -m repro.launch.serve --preempt-after N`` drives exactly this
kill → restart cycle:

    PYTHONPATH=src python examples/solve_service.py --checkpoint-dir /tmp/ck
    # ... SIGTERM mid-flush → "PREEMPTED at segment k", exit 75 ...
    PYTHONPATH=src python examples/solve_service.py --checkpoint-dir /tmp/ck \\
        --resume

``--path N`` additionally submits N regularization-path requests
(DESIGN.md §13): each is a λ grid answered by one ``PathSolution`` whose
per-λ points carry full δ̃/m certificates, solved off ONE one-touch sketch
pass with x and the sketch level warm-started point-to-point. The demo
then re-submits one grid verbatim to show the fingerprint ladder cache
serving repeated-A traffic without touching A (``cache_hit=True``,
``sketch_passes=0``):

    PYTHONPATH=src python examples/solve_service.py --requests 8 --path 4
"""

import argparse
import shutil
import signal
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import PreemptedError, direct_solve, from_least_squares
from repro.core.level_grams import COMPUTE_DTYPES, PADDED_SKETCHES
from repro.serve.solver_service import SolverService


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--sketch", default="gaussian",
                    choices=PADDED_SKETCHES,
                    help="sketch family for the adaptive engine")
    ap.add_argument("--dtype", default="fp32", choices=COMPUTE_DTYPES,
                    help="sketch-pass compute dtype (DESIGN.md §10): "
                         "bf16/int8 reduce stream precision, certificates "
                         "stay fp32")
    ap.add_argument("--requests", type=int, default=40)
    ap.add_argument("--certificates", type=int, default=8,
                    help="how many per-request certificate lines to print")
    ap.add_argument("--tol", type=float, default=1e-12)
    ap.add_argument("--max-iters", type=int, default=100)
    ap.add_argument("--max-retries", type=int, default=2)
    ap.add_argument("--no-fallback", action="store_true",
                    help="disable the dense direct_solve fallback")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="wall-clock budget for the whole flush; expired "
                         "requests return DEADLINE_EXCEEDED with their "
                         "best finite iterate (DESIGN.md §11)")
    ap.add_argument("--segment-trips", type=int, default=32,
                    help="loop trips per dispatched segment when the solve "
                         "runs preemptibly")
    ap.add_argument("--checkpoint-dir", default="",
                    help="checkpoint in-flight solver state here; SIGTERM "
                         "then exits 75 after committing, and --resume "
                         "continues from the committed segment")
    ap.add_argument("--resume", action="store_true",
                    help="resume from --checkpoint-dir instead of wiping it")
    ap.add_argument("--path", type=int, default=0,
                    help="additionally submit this many regularization-path "
                         "requests (8-point λ grids, one sketch pass each) "
                         "and a repeated-A cache-hit round — DESIGN.md §13")
    args = ap.parse_args(argv)

    preempt = None
    if args.checkpoint_dir:
        if not args.resume:
            shutil.rmtree(args.checkpoint_dir, ignore_errors=True)
        from repro.ft import PreemptionHandler

        preempt = PreemptionHandler(signals=(signal.SIGTERM,))
        preempt.__enter__()

    svc = SolverService(batch_size=16, method="pcg", sketch=args.sketch,
                        compute_dtype=args.dtype, tol=args.tol,
                        max_iters=args.max_iters,
                        max_retries=args.max_retries,
                        fallback=not args.no_fallback,
                        segment_trips=args.segment_trips,
                        checkpoint_dir=args.checkpoint_dir or None,
                        preempt=preempt, ladder_cache=bool(args.path))
    rng = np.random.default_rng(0)
    requests = {}
    for i in range(args.requests):
        n = int(rng.integers(64, 1500))
        d = int(rng.integers(8, 100))
        A = jax.random.normal(jax.random.PRNGKey(2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (n,))
        nu = float(rng.uniform(0.05, 0.5))
        rid = svc.submit(A, y, nu)
        requests[rid] = (A, y, nu)
    path_requests = {}
    for i in range(args.path):
        n = int(rng.integers(64, 1500))
        d = int(rng.integers(8, 100))
        A = jax.random.normal(
            jax.random.PRNGKey(50_000 + 2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(50_001 + 2 * i), (n,))
        nus = np.geomspace(1.0, 1e-2, 8)   # strong→weak: warm downhill
        rid = svc.submit_path(A, y, nus)
        path_requests[rid] = (A, y, nus)

    t0 = time.perf_counter()
    try:
        sols = svc.flush(deadline_s=args.deadline_s)
    except PreemptedError as e:
        print(f"PREEMPTED at segment {e.segment} "
              f"(state committed to {e.checkpoint_dir}); "
              f"re-run with --resume to continue", flush=True)
        sys.exit(75)   # EX_TEMPFAIL: restart me
    dt = time.perf_counter() - t0

    counts: dict[str, int] = {}
    for s in sols.values():
        counts[s.status] = counts.get(s.status, 0) + 1
    path_sols = {rid: s for rid, s in sols.items() if rid in path_requests}
    ridge_sols = {rid: s for rid, s in sols.items()
                  if rid not in path_requests}
    all_finite = all(
        bool(jnp.all(jnp.isfinite(s.x))) for s in ridge_sols.values()
    ) and all(bool(jnp.all(jnp.isfinite(p.x)))
              for s in path_sols.values() for p in s.points)

    ok = {rid: s for rid, s in ridge_sols.items() if s.converged}
    worst = 0.0
    for rid, s in ok.items():
        A, y, nu = requests[rid]
        x_star = direct_solve(from_least_squares(A, y, nu))
        rel = float(jnp.linalg.norm(s.x - x_star) / jnp.linalg.norm(x_star))
        worst = max(worst, rel)
    # path audit: every λ point against its own dense direct solve
    for rid, s in path_sols.items():
        if not s.converged:
            continue
        A, y, nus = path_requests[rid]
        for p in s.points:
            x_star = direct_solve(from_least_squares(A, y, p.nu))
            rel = float(jnp.linalg.norm(p.x - x_star)
                        / jnp.linalg.norm(x_star))
            worst = max(worst, rel)

    print(f"{len(requests) + len(path_requests)} requests in {dt:.2f}s "
          f"(incl. compile; {svc.stats['batches']} batches, "
          f"{svc.stats['padded_slots']} padded slots)")
    print("statuses: "
          + ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
          + f"; segments={svc.stats['segments']}, "
            f"resumed_chunks={svc.stats['resumed_chunks']}, "
            f"deadline_exceeded={svc.stats['deadline_exceeded']}")
    print(f"ALL_FINITE={int(all_finite)}")
    if ok:
        m_finals = sorted(s.m_final for s in ok.values())
        print(f"worst relative error vs direct solve: {worst:.2e}")
        print(f"adapted sketch sizes m_final: min={m_finals[0]} "
              f"median={m_finals[len(m_finals) // 2]} max={m_finals[-1]}")
    for rid in sorted(ok)[: args.certificates]:
        s = ok[rid]
        print(f"  cert req={rid:3d} sketch={s.sketch:<14s} "
              f"dtype={s.compute_dtype:<4s} "
              f"class=(n={s.shape_class.n}, d={s.shape_class.d}, "
              f"m_max={s.shape_class.m_max}) m_final={s.m_final:4d} "
              f"iters={s.iters:3d} doublings={s.doublings} "
              f"δ̃={s.delta_tilde:.2e}")
    if path_sols:
        s0 = next(iter(path_sols.values()))
        print(f"path: {sum(s.converged for s in path_sols.values())}/"
              f"{len(path_sols)} grids converged, "
              f"{sum(s.sketch_passes for s in path_sols.values())} "
              f"one-touch passes for "
              f"{sum(len(s.points) for s in path_sols.values())} λ points; "
              f"warm m trajectory (req {s0.req_id}): "
              f"{tuple(p.m_final for p in s0.points)}")
        # repeated-A: the fingerprint cache serves the λ-free ladder, the
        # re-submitted grid never touches A
        rid0 = min(path_requests)
        A, y, nus = path_requests[rid0]
        rid_warm = svc.submit_path(A, y, nus)
        warm = svc.flush()[rid_warm]
        match = all(bool(jnp.allclose(pw.x, pc.x)) for pw, pc in
                    zip(warm.points, path_sols[rid0].points))
        print(f"repeat-A path round: cache_hit={warm.cache_hit}, "
              f"sketch_passes={warm.sketch_passes}, "
              f"identical_solutions={int(match)}")


if __name__ == "__main__":
    main()
