"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps on the synthetic pipeline, with checkpointing + resume.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]

(~100M params: d_model=512, 8 layers, vocab=32k — the full launcher
machinery: sharding, AdamW, remat, watchdog, preemption handler.)
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.sharding import param_specs
from repro.ft import CheckpointManager, PreemptionHandler, StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.train import AdamWConfig, TrainConfig, init_opt_state
from repro.train.step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2-0.5b"),
        name="qwen2-100m",
        n_layers=8,
        d_model=512,
        n_heads=8,
        n_kv_heads=2,
        head_dim=64,
        d_ff=2048,
        vocab=32_768,
    )
    print(f"model: {cfg.name}  params={cfg.param_count()/1e6:.0f}M")

    mesh = make_host_mesh()
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=args.seq)
    p_sh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(cfg, params, mesh))
    params = jax.device_put(params, p_sh)
    opt = init_opt_state(params)

    tcfg = TrainConfig(
        opt=AdamWConfig(lr=3e-4, warmup_steps=50, total_steps=args.steps),
        num_microbatches=2,
        compute_dtype=jnp.bfloat16,
    )
    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))
    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    wd = StragglerWatchdog()

    start = 0
    if ckpt.latest_step():
        (params, opt), extra = ckpt.restore((params, opt))
        data.restore(extra["data"])
        start = extra["step"]
        print(f"resumed at step {start}")

    with mesh, PreemptionHandler() as pre:
        for step in range(start, args.steps):
            t0 = time.perf_counter()
            batch = jax.tree.map(jnp.asarray, next(data))
            params, opt, m = step_fn(params, opt, batch)
            wd.record(time.perf_counter() - t0)
            if (step + 1) % 25 == 0:
                print(f"step {step+1:4d}  loss={float(m['loss']):.4f}  "
                      f"lr={float(m['lr']):.2e}  "
                      f"({time.perf_counter()-t0:.2f}s/step)")
            if (step + 1) % 100 == 0 or pre.should_stop:
                ckpt.save(step + 1, (params, opt),
                          extra={"step": step + 1, "data": data.state()},
                          blocking=False)
            if pre.should_stop:
                break
    ckpt.wait()
    print("done; final loss", float(m["loss"]))


if __name__ == "__main__":
    main()
