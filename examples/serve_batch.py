"""Batched serving example: prefill a batch of prompts, then greedy-decode
continuations with the KV-cache/state machinery (works for every assigned
arch — attention caches, ring buffers, RG-LRU and RWKV states).

    PYTHONPATH=src python examples/serve_batch.py [--arch rwkv6-3b]
"""

import argparse
import time

import jax

from repro.configs import get_config
from repro.serve.step import greedy_generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-27b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    from repro.models import init_params

    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=128)
    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.n_enc_layers:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model)
        )
    t0 = time.perf_counter()
    out = greedy_generate(
        params, cfg, prompts, args.new_tokens,
        max_seq=args.prompt_len + args.new_tokens + 1, enc_feats=enc,
    )
    dt = time.perf_counter() - t0
    toks = args.batch * args.new_tokens
    print(f"arch={cfg.name}  generated {out.shape} in {dt:.2f}s "
          f"({toks/dt:.1f} tok/s on CPU)")
    print("sample continuation ids:", out[0, :12].tolist())


if __name__ == "__main__":
    main()
