"""Quickstart: solve a regularized least-squares problem with the paper's
adaptive sketching PCG and compare against direct / CG baselines.

    PYTHONPATH=src python examples/quickstart.py

``--logistic`` instead runs the GLM quickstart (DESIGN.md §8): a batch of
logistic-ridge problems through the adaptive sketched-Newton driver, whose
inner weighted subproblems run on the padded engine with warm-started
sketch ladders; compared against an exact-IRLS reference. ``--small``
shrinks both modes to CI scale.

    PYTHONPATH=src python examples/quickstart.py --logistic [--small]
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    direct_solve,
    effective_dimension,
    from_least_squares,
)
from repro.core.effective_dim import exp_decay_singular_values


def main_logistic(small: bool = False):
    """GLM quickstart: B logistic-ridge problems, one sketched-Newton call."""
    import numpy as np

    from repro.core import adaptive_newton_solve_batched, irls_reference
    from repro.core.objectives import synthetic_logistic_batch

    B, n, d, m_max = (4, 256, 16, 32) if small else (8, 2048, 64, 128)
    nu = 0.3
    A, Y = synthetic_logistic_batch(jax.random.PRNGKey(0), B, n, d)
    print(f"logistic-ridge batch: B={B} n={n} d={d} ν={nu} m_max={m_max}")

    t0 = time.perf_counter()
    x, stats = adaptive_newton_solve_batched(
        "logistic", A, Y, nu, m_max=m_max, keys=jax.random.PRNGKey(1))
    t_newton = time.perf_counter() - t0
    x_ref = irls_reference("logistic", A, Y, nu)
    rel = float(jnp.max(jnp.linalg.norm(x - x_ref, axis=1)
                        / jnp.linalg.norm(x_ref, axis=1)))
    outer = np.asarray(stats["newton_iters"])
    print(f"sketched Newton:        {t_newton:6.2f}s  "
          f"max rel_err vs IRLS = {rel:.2e}")
    print(f"certificates: converged {int(np.sum(np.asarray(stats['converged'])))}"
          f"/{B}, outer iters {outer.min()}–{outer.max()}, "
          f"max decrement λ̃²/2 = "
          f"{float(jnp.max(stats['decrement'])):.2e}")
    print(f"warm-started m trajectory (problem 0): "
          f"{stats['m_trajectory'][:, 0].tolist()}")


def main(small: bool = False):
    # Build an ill-conditioned ridge problem (exponential spectral decay,
    # the paper's §6 setting).
    n, d, nu = (1024, 128, 1e-2) if small else (8192, 1024, 1e-2)
    key = jax.random.PRNGKey(0)
    sv = exp_decay_singular_values(d, 0.99)
    kU, kV, ky = jax.random.split(key, 3)
    U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
    V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
    A = (U * sv[None, :]) @ V.T
    y = jax.random.normal(ky, (n,))
    q = from_least_squares(A, y, nu)
    d_e = float(effective_dimension(sv, nu))
    print(f"problem: n={n} d={d} ν={nu}  effective dimension d_e≈{d_e:.0f}")

    t0 = time.perf_counter()
    x_star = jax.block_until_ready(direct_solve(q))
    t_direct = time.perf_counter() - t0
    print(f"direct Cholesky:        {t_direct:6.2f}s")

    t0 = time.perf_counter()
    x_cg, _ = cg_solve(q, jnp.zeros((d,)), iters=300)
    x_cg = jax.block_until_ready(x_cg)
    t_cg = time.perf_counter() - t0
    err = float(jnp.linalg.norm(x_cg - x_star) / jnp.linalg.norm(x_star))
    print(f"CG (300 iters):         {t_cg:6.2f}s  rel_err={err:.2e}")

    t0 = time.perf_counter()
    res = adaptive_solve(
        q,
        AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=100, tol=1e-10),
        key=jax.random.PRNGKey(1),
    )
    t_ada = time.perf_counter() - t0
    err = float(jnp.linalg.norm(res.x - x_star) / jnp.linalg.norm(x_star))
    print(
        f"adaptive PCG (paper):   {t_ada:6.2f}s  rel_err={err:.2e}  "
        f"iters={res.iters}  doublings={res.n_doublings}  "
        f"final sketch m={res.m_final} (vs 2d={2*d}, d_e≈{d_e:.0f})"
    )


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--logistic", action="store_true",
                    help="run the GLM quickstart (sketched Newton)")
    ap.add_argument("--small", action="store_true",
                    help="CI-scale problem sizes")
    args = ap.parse_args()
    if args.logistic:
        main_logistic(small=args.small)
    else:
        main(small=args.small)
