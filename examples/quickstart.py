"""Quickstart: solve a regularized least-squares problem with the paper's
adaptive sketching PCG and compare against direct / CG baselines.

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax
import jax.numpy as jnp

from repro.core import (
    AdaptiveConfig,
    adaptive_solve,
    cg_solve,
    direct_solve,
    effective_dimension,
    from_least_squares,
)
from repro.core.effective_dim import exp_decay_singular_values


def main():
    # Build an ill-conditioned ridge problem (exponential spectral decay,
    # the paper's §6 setting).
    n, d, nu = 8192, 1024, 1e-2
    key = jax.random.PRNGKey(0)
    sv = exp_decay_singular_values(d, 0.99)
    kU, kV, ky = jax.random.split(key, 3)
    U, _ = jnp.linalg.qr(jax.random.normal(kU, (n, d)))
    V, _ = jnp.linalg.qr(jax.random.normal(kV, (d, d)))
    A = (U * sv[None, :]) @ V.T
    y = jax.random.normal(ky, (n,))
    q = from_least_squares(A, y, nu)
    d_e = float(effective_dimension(sv, nu))
    print(f"problem: n={n} d={d} ν={nu}  effective dimension d_e≈{d_e:.0f}")

    t0 = time.perf_counter()
    x_star = jax.block_until_ready(direct_solve(q))
    t_direct = time.perf_counter() - t0
    print(f"direct Cholesky:        {t_direct:6.2f}s")

    t0 = time.perf_counter()
    x_cg, _ = cg_solve(q, jnp.zeros((d,)), iters=300)
    x_cg = jax.block_until_ready(x_cg)
    t_cg = time.perf_counter() - t0
    err = float(jnp.linalg.norm(x_cg - x_star) / jnp.linalg.norm(x_star))
    print(f"CG (300 iters):         {t_cg:6.2f}s  rel_err={err:.2e}")

    t0 = time.perf_counter()
    res = adaptive_solve(
        q,
        AdaptiveConfig(method="pcg", sketch="sjlt", max_iters=100, tol=1e-10),
        key=jax.random.PRNGKey(1),
    )
    t_ada = time.perf_counter() - t0
    err = float(jnp.linalg.norm(res.x - x_star) / jnp.linalg.norm(x_star))
    print(
        f"adaptive PCG (paper):   {t_ada:6.2f}s  rel_err={err:.2e}  "
        f"iters={res.iters}  doublings={res.n_doublings}  "
        f"final sketch m={res.m_final} (vs 2d={2*d}, d_e≈{d_e:.0f})"
    )


if __name__ == "__main__":
    main()
