"""Serving steps: prefill (build the KV/state cache) and decode (one token).

``decode_*`` / ``long_*`` dry-run cells lower ``decode_step`` with a
seq_len-sized cache; ``prefill_*`` cells lower ``prefill_step``.
"""

from __future__ import annotations


import jax.numpy as jnp

from repro.models import build_cross_cache, encode, forward, init_cache
from repro.models.config import ModelConfig


def prefill_step(params, cfg: ModelConfig, tokens, cache, *,
                 enc_feats=None, compute_dtype=jnp.bfloat16,
                 scan_unroll: bool = False):
    """Process a (B, S) prompt from an empty cache. Returns
    (last-token logits (B, V), filled cache)."""
    if cfg.n_enc_layers and enc_feats is not None:
        enc_out = encode(params, cfg, enc_feats, compute_dtype)
        cc = build_cross_cache(params, cfg, enc_out)
        cache = dict(cache)
        cache["blocks"] = {
            k: (cache["blocks"][k] | cc["blocks"][k])
            if k in cc["blocks"] else cache["blocks"][k]
            for k in cache["blocks"]
        }
        cache["rem"] = {
            k: (cache["rem"][k] | cc["rem"][k])
            if k in cc["rem"] else cache["rem"][k]
            for k in cache["rem"]
        }
    logits, cache = forward(
        params, cfg, tokens, cache=cache,
        cache_pos=jnp.zeros((), jnp.int32), compute_dtype=compute_dtype,
        scan_unroll=scan_unroll,
    )
    return logits[:, -1], cache


def decode_step(params, cfg: ModelConfig, token, cache, cache_pos, *,
                compute_dtype=jnp.bfloat16, scan_unroll: bool = False):
    """One decode step. token: (B, 1) int32; cache_pos: scalar int32
    (number of tokens already in the cache). Returns (logits (B, V), cache)."""
    logits, cache = forward(
        params, cfg, token, cache=cache, cache_pos=cache_pos,
        compute_dtype=compute_dtype, scan_unroll=scan_unroll,
    )
    return logits[:, -1], cache


def greedy_generate(params, cfg: ModelConfig, prompt, max_new: int, *,
                    max_seq: int, enc_feats=None,
                    compute_dtype=jnp.float32):
    """Simple batched greedy generation loop (examples/serving)."""
    B, S = prompt.shape
    cache = init_cache(cfg, B, max_seq, dtype=compute_dtype)
    logits, cache = prefill_step(
        params, cfg, prompt, cache, enc_feats=enc_feats,
        compute_dtype=compute_dtype,
    )
    tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    out = [tok]
    pos = S
    for _ in range(max_new - 1):
        logits, cache = decode_step(
            params, cfg, tok, cache, jnp.asarray(pos, jnp.int32),
            compute_dtype=compute_dtype,
        )
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
        pos += 1
    return jnp.concatenate(out, axis=1)
