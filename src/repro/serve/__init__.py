from .solver_service import (
    DEFAULT_SHAPE_CLASSES,
    RidgeRequest,
    RidgeSolution,
    ShapeClass,
    SolverService,
)
from .step import decode_step, greedy_generate, prefill_step
