from .step import decode_step, greedy_generate, prefill_step
