"""Ridge-solve serving path on top of the batched padded engine.

Production traffic is many *small heterogeneous* ridge problems (per-user /
per-tenant heads, per-λ sweeps, one-hot class blocks), not one big solve.
A fixed-shape accelerator executable cannot chase every (n, d): instead the
service

1. **buckets** each request into a fixed (n, d, m_max) *shape class* — the
   smallest configured class that fits; A is zero-padded to (n_c, d_c) with
   Λ = 1 on padded coordinates, which block-diagonalizes H so the padded
   solution restricted to the original coordinates is EXACTLY the original
   solution (padded coords solve ν²x = 0 ⇒ 0);
2. **packs** up to ``batch_size`` requests per class into one batched
   ``Quadratic`` (padding short batches with trivial b = 0 problems that
   converge at initialization);
3. **solves** the batch in one call of the fully-jitted multi-problem
   adaptive engine (``core.adaptive_padded``) — per-problem doubling, one
   executable per shape class, with a per-class ``sketch=`` family
   (streamed gaussian / sjlt / srht; the streaming providers keep the
   precompute at O(B·d²·L) live bytes, which is what lets large-n shape
   classes exist at all);
4. **returns** per-request solutions with their adaptivity *certificates*
   (δ̃, m_final, iterations, doublings) so callers can audit convergence.

CPU-scale demo wiring lives in ``launch/serve.py --ridge`` and
``examples/solve_service.py``; the batched-vs-looped engine comparison is
``benchmarks/bench_batched.py``. See DESIGN.md §6.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.adaptive_padded import padded_adaptive_solve_batched
from repro.core.quadratic import Quadratic


class ShapeClass(NamedTuple):
    n: int       # padded row count
    d: int       # padded feature count
    m_max: int   # padded sketch budget for the class
    sketch: str | None = None   # per-class sketch family (None → service
                                # default): large-n classes pick ``srht``
                                # (one FWHT pass) or keep the streamed
                                # ``gaussian`` — both run in O(B·d²·L) live
                                # memory, where the old dense Gaussian
                                # needed O(B·m_max·n) and could not hold
                                # these shapes


DEFAULT_SHAPE_CLASSES = (
    ShapeClass(n=256, d=32, m_max=64),
    ShapeClass(n=1024, d=64, m_max=128),
    ShapeClass(n=2048, d=128, m_max=256),
    ShapeClass(n=4096, d=256, m_max=512),
    # large-n tail: viable only with streaming sketch→Gram providers
    ShapeClass(n=16384, d=256, m_max=512, sketch="srht"),
)


@dataclasses.dataclass(frozen=True)
class RidgeRequest:
    req_id: int
    A: jnp.ndarray           # (n, d) features
    y: jnp.ndarray           # (n,) targets
    nu: float                # regularization ν
    lam_diag: jnp.ndarray | None = None


@dataclasses.dataclass(frozen=True)
class RidgeSolution:
    req_id: int
    x: jnp.ndarray           # (d,) solution in the request's coordinates
    delta_tilde: float       # certificate: final δ̃ (eq. 2.3)
    m_final: int             # certificate: adapted sketch size
    iters: int               # accepted iterations
    doublings: int
    shape_class: ShapeClass
    batch_index: int         # slot in the packed batch (observability)
    sketch: str = "gaussian"  # sketch family that produced the certificate


class SolverService:
    """Shape-class bucketing + batch packing over the padded adaptive engine.

    ``submit`` enqueues; ``flush`` drains every bucket in fixed-size batches
    through one compiled executable per shape class and returns solutions
    keyed by request id. The service is deterministic: request k is solved
    with ``fold_in(base_key, k)`` regardless of what it is packed with.
    """

    def __init__(
        self,
        shape_classes: Iterable[ShapeClass] = DEFAULT_SHAPE_CLASSES,
        *,
        batch_size: int = 16,
        method: str = "pcg",
        sketch: str = "gaussian",
        rho: float = 0.5,
        tol: float = 1e-10,
        max_iters: int = 200,
        seed: int = 0,
    ):
        self.shape_classes = sorted(shape_classes,
                                    key=lambda c: (c.n, c.d, c.m_max))
        self.batch_size = batch_size
        self.method = method
        self.sketch = sketch
        self.rho = rho
        self.tol = tol
        self.max_iters = max_iters
        self._base_key = jax.random.PRNGKey(seed)
        self._queues: dict[ShapeClass, list[RidgeRequest]] = {
            c: [] for c in self.shape_classes}
        self._next_id = 0
        self.stats = {"requests": 0, "batches": 0, "padded_slots": 0,
                      "solve_seconds": 0.0}

    # -- bucketing ---------------------------------------------------------
    def bucket_for(self, n: int, d: int) -> ShapeClass:
        """Smallest configured shape class that fits an (n, d) request."""
        for c in self.shape_classes:
            if n <= c.n and d <= c.d:
                return c
        raise ValueError(
            f"no shape class fits (n={n}, d={d}); "
            f"largest is {self.shape_classes[-1]}")

    def submit(self, A, y, nu, lam_diag=None) -> int:
        """Enqueue one ridge problem; returns its request id."""
        A = jnp.asarray(A)
        y = jnp.asarray(y)
        req = RidgeRequest(req_id=self._next_id, A=A, y=y, nu=float(nu),
                           lam_diag=lam_diag)
        self._next_id += 1
        self._queues[self.bucket_for(*A.shape)].append(req)
        self.stats["requests"] += 1
        return req.req_id

    # -- packing -----------------------------------------------------------
    def _pack(self, cls: ShapeClass, reqs: list[RidgeRequest]):
        """Pad each request to the class shape and stack; pad the batch to
        ``batch_size`` with trivial (b = 0) problems.

        Staged in host numpy buffers (in-place writes) with ONE device
        transfer per field — out-of-jit `.at[i].set` would copy the full
        padded batch buffer once per request."""
        import numpy as np

        B = self.batch_size
        dtype = np.dtype(reqs[0].A.dtype)
        A = np.zeros((B, cls.n, cls.d), dtype)
        b = np.zeros((B, cls.d), dtype)
        nu = np.ones((B,), dtype)
        lam = np.ones((B, cls.d), dtype)
        keys = np.zeros((B,) + self._base_key.shape,
                        np.asarray(self._base_key).dtype)
        for i, r in enumerate(reqs):
            ni, di = r.A.shape
            A[i, :ni, :di] = np.asarray(r.A, dtype)
            b[i, :di] = np.asarray(r.A.T @ r.y, dtype)
            nu[i] = r.nu
            if r.lam_diag is not None:
                lam[i, :di] = np.asarray(r.lam_diag, dtype)
            keys[i] = np.asarray(
                jax.random.fold_in(self._base_key, r.req_id))
        q = Quadratic(A=jnp.asarray(A), b=jnp.asarray(b), nu=jnp.asarray(nu),
                      lam_diag=jnp.asarray(lam), batched=True)
        return q, jnp.asarray(keys)

    # -- solving -----------------------------------------------------------
    def flush(self) -> dict[int, RidgeSolution]:
        """Solve everything queued; returns {req_id: RidgeSolution}."""
        out: dict[int, RidgeSolution] = {}
        for cls in self.shape_classes:
            queue, self._queues[cls] = self._queues[cls], []
            for i in range(0, len(queue), self.batch_size):
                out.update(self._solve_chunk(cls, queue[i: i + self.batch_size]))
        return out

    def _solve_chunk(self, cls: ShapeClass, reqs: list[RidgeRequest]):
        q, keys = self._pack(cls, reqs)
        sketch = cls.sketch or self.sketch
        t0 = time.perf_counter()
        x, stats = padded_adaptive_solve_batched(
            q, keys, m_max=cls.m_max, method=self.method, sketch=sketch,
            max_iters=self.max_iters, rho=self.rho, tol=self.tol)
        x = jax.block_until_ready(x)
        self.stats["solve_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["padded_slots"] += self.batch_size - len(reqs)
        out = {}
        for i, r in enumerate(reqs):
            di = r.A.shape[1]
            out[r.req_id] = RidgeSolution(
                req_id=r.req_id,
                x=x[i, :di],
                delta_tilde=float(stats["dtilde"][i]),
                m_final=int(stats["m_final"][i]),
                iters=int(stats["iters"][i]),
                doublings=int(stats["doublings"][i]),
                shape_class=cls,
                batch_index=i,
                sketch=sketch,
            )
        return out

    def solve_one(self, A, y, nu, lam_diag=None) -> RidgeSolution:
        """Convenience: submit + flush a single request (still batched —
        the padded slots ride along as no-op problems)."""
        rid = self.submit(A, y, nu, lam_diag)
        return self.flush()[rid]
