"""Ridge-solve serving path on top of the batched padded engine.

Production traffic is many *small heterogeneous* ridge problems (per-user /
per-tenant heads, per-λ sweeps, one-hot class blocks), not one big solve.
A fixed-shape accelerator executable cannot chase every (n, d): instead the
service

1. **buckets** each request into a fixed (n, d, m_max) *shape class* — the
   smallest configured class that fits; A is zero-padded to (n_c, d_c) with
   Λ = 1 on padded coordinates, which block-diagonalizes H so the padded
   solution restricted to the original coordinates is EXACTLY the original
   solution (padded coords solve ν²x = 0 ⇒ 0);
2. **packs** up to ``batch_size`` requests per class into one batched
   ``Quadratic`` (padding short batches with trivial b = 0 problems that
   converge at initialization);
3. **solves** the batch in one call of the fully-jitted multi-problem
   adaptive engine (``core.adaptive_padded``) — per-problem doubling, one
   executable per shape class, with a per-class ``sketch=`` family
   (streamed gaussian / sjlt / srht; the streaming providers keep the
   precompute at O(B·d²·L) live bytes, which is what lets large-n shape
   classes exist at all);
4. **returns** per-request solutions with their adaptivity *certificates*
   (δ̃, m_final, iterations, doublings) so callers can audit convergence.

GLM traffic (DESIGN.md §8): ``submit_glm`` takes the same (A, y, ν) with a
``family`` — logistic / poisson / huber — and rides the SAME shape-class /
packing machinery; a packed GLM batch is solved by the adaptive sketched-
Newton driver (``core.newton``), whose inner weighted subproblems run on
the padded engine with per-problem warm-started sketch ladders. Solutions
carry Newton-level certificates: outer iterations, the final Newton
decrement λ̃²/2, and the per-step m trajectory.

Path traffic (DESIGN.md §13): ``submit_path`` takes (A, y, a λ GRID) and
returns one ``PathSolution`` whose per-λ ``PathPoint``s each carry the
full δ̃/m/status certificate. A packed path chunk runs
``core.robust.robust_path_solve_batched``: ONE one-touch sketch pass
serves the whole grid (the ladder-level Grams are λ-free; the ν²Λ shift
enters at factorization), with x and the per-problem sketch level
warm-started point-to-point.

Ladder cache (opt-in ``ladder_cache=True``): the λ-free ladder is ALSO
reusable across *requests* that share (A, Λ, sketch family,
compute_dtype). The service fingerprints that identity, keys each slot's
sketch off the fingerprint instead of the request id (identical data ⇒
identical sketch ⇒ the cached per-slot ladder slice is exactly what the
pass would recompute), and serves warm repeated-A traffic — per-tenant
heads, λ re-sweeps — without touching A at all. Solutions record
``cache_hit``; the first slice of the continuous-batching roadmap item.

CPU-scale demo wiring lives in ``launch/serve.py --ridge`` (plus ``--glm``)
and ``examples/solve_service.py``; the batched-vs-looped engine comparison
is ``benchmarks/bench_batched.py``. See DESIGN.md §6/§8.
"""

from __future__ import annotations

import dataclasses
import math
import time
from collections import OrderedDict
from typing import Iterable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.adaptive_padded import doubling_ladder, prepare_path_ladder
from repro.core.distributed import n_data_shards, shard_quadratic
from repro.core.newton import adaptive_newton_solve_batched
from repro.core.objectives import get_objective
from repro.core.quadratic import Quadratic
from repro.core.robust import (
    robust_padded_solve_batched,
    robust_path_solve_batched,
)
from repro.core.status import SolveStatus, status_name


class ShapeClass(NamedTuple):
    n: int       # padded row count
    d: int       # padded feature count
    m_max: int   # padded sketch budget for the class
    sketch: str | None = None   # per-class sketch family (None → service
                                # default): large-n classes pick ``srht``
                                # (one FWHT pass) or keep the streamed
                                # ``gaussian`` — both run in O(B·d²·L) live
                                # memory, where the old dense Gaussian
                                # needed O(B·m_max·n) and could not hold
                                # these shapes
    compute_dtype: str | None = None  # per-class sketch-pass precision
                                # (None → service default): "bf16" halves
                                # the large-n classes' stream bandwidth,
                                # "int8" serves quantized features
                                # (kernels.precision); certificates stay
                                # fp32 and record the mode used


DEFAULT_SHAPE_CLASSES = (
    ShapeClass(n=256, d=32, m_max=64),
    ShapeClass(n=1024, d=64, m_max=128),
    ShapeClass(n=2048, d=128, m_max=256),
    ShapeClass(n=4096, d=256, m_max=512),
    # large-n tail: viable only with streaming sketch→Gram providers
    ShapeClass(n=16384, d=256, m_max=512, sketch="srht"),
)

# Sharded services (mesh=...) additionally serve the pod-scale tail: a
# single device cannot hold the packed (B, n, d) batch at n=65536, but
# each data shard only sees n/K rows and the one-touch pass psums the
# (L, B, d, d) level Grams (DESIGN.md §5). This is the default for
# SolverService(mesh=...); a mesh-less service keeps rejecting such
# requests with the clear "no shape class fits" error.
SHARDED_SHAPE_CLASSES = DEFAULT_SHAPE_CLASSES + (
    ShapeClass(n=65536, d=256, m_max=512, sketch="srht"),
)


@dataclasses.dataclass(frozen=True)
class RidgeRequest:
    req_id: int
    A: jnp.ndarray           # (n, d) features
    y: jnp.ndarray           # (n,) targets
    nu: float                # regularization ν
    lam_diag: jnp.ndarray | None = None
    deadline: float | None = None   # absolute time.perf_counter() stamp


@dataclasses.dataclass(frozen=True)
class PathRequest:
    req_id: int
    A: jnp.ndarray           # (n, d) features
    y: jnp.ndarray           # (n,) targets
    nus: tuple               # λ grid (ν values), walked in order — sort
                             # strong→weak so warm starts move downhill
    lam_diag: jnp.ndarray | None = None
    deadline: float | None = None   # absolute time.perf_counter() stamp


@dataclasses.dataclass(frozen=True)
class GLMRequest:
    req_id: int
    A: jnp.ndarray           # (n, d) features
    y: jnp.ndarray           # (n,) targets (labels / counts / responses)
    nu: float                # regularization ν
    family: str              # "logistic" | "poisson" | "huber[:delta]"
    lam_diag: jnp.ndarray | None = None
    deadline: float | None = None   # absolute time.perf_counter() stamp


@dataclasses.dataclass(frozen=True)
class GLMSolution:
    req_id: int
    x: jnp.ndarray           # (d,) solution in the request's coordinates
    family: str
    decrement: float         # certificate: final Newton decrement λ̃²/2
    converged: bool          # decrement cleared the service tolerance
    newton_iters: int        # accepted outer Newton steps
    m_trajectory: tuple      # certificate: inner m_final after each step
    m_final: int             # last adapted sketch size
    inner_iters: int         # total inner (PCG/IHS) iterations
    shape_class: ShapeClass
    batch_index: int
    sketch: str = "gaussian"
    # sketch-pass precision that produced this certificate (the δ̃/decrement
    # numbers themselves are always fp32 — DESIGN.md §10)
    compute_dtype: str = "fp32"
    # failure-lattice verdict (DESIGN.md §9); names from SolveStatus
    status: str = "OK"
    stalled: bool = False    # terminated above tolerance (distinct from
                             # "done": frozen line search / outer budget)
    retries: int = 0         # sketch redraws consumed (0 on the GLM path)
    fell_back: bool = False  # answer from the dense fallback, no certificate


@dataclasses.dataclass(frozen=True)
class RidgeSolution:
    req_id: int
    x: jnp.ndarray           # (d,) solution in the request's coordinates
    delta_tilde: float       # certificate: final δ̃ (eq. 2.3)
    m_final: int             # certificate: adapted sketch size
    iters: int               # accepted iterations
    doublings: int
    shape_class: ShapeClass
    batch_index: int         # slot in the packed batch (observability)
    sketch: str = "gaussian"  # sketch family that produced the certificate
    # sketch-pass precision that produced this certificate (the δ̃ value
    # itself is always fp32 — DESIGN.md §10)
    compute_dtype: str = "fp32"
    # failure-lattice verdict (DESIGN.md §9); names from SolveStatus
    status: str = "OK"
    converged: bool = True   # δ̃ cleared the service tolerance
    stalled: bool = False    # terminated above tolerance — previously this
                             # was folded into "done" and indistinguishable
                             # from convergence without re-deriving it from δ̃
    retries: int = 0         # sketch redraws consumed before this answer
    fell_back: bool = False  # answer from direct_solve, no δ̃ certificate
    cache_hit: bool = False  # the λ-free ladder came from the fingerprint
                             # cache — this answer skipped the sketch pass


@dataclasses.dataclass(frozen=True)
class PathPoint:
    """One λ point of a ``PathSolution`` — the same certificate surface a
    single ``RidgeSolution`` carries, per grid point."""
    nu: float
    x: jnp.ndarray           # (d,) solution in the request's coordinates
    delta_tilde: float       # certificate: final δ̃ (eq. 2.3) at this λ
    m_final: int             # certificate: adapted sketch size at this λ
    iters: int
    doublings: int
    status: str = "OK"
    converged: bool = True
    retries: int = 0
    fell_back: bool = False


@dataclasses.dataclass(frozen=True)
class PathSolution:
    req_id: int
    points: tuple            # P PathPoints, in the request's grid order
    shape_class: ShapeClass
    batch_index: int
    sketch: str = "gaussian"
    compute_dtype: str = "fp32"
    status: str = "OK"       # OK iff every point converged, else the first
                             # non-converged point's status
    converged: bool = True   # every point cleared the service tolerance
    cache_hit: bool = False  # the ladder came from the fingerprint cache
    sketch_passes: int = 1   # one-touch passes this request's chunk paid
                             # for the WHOLE grid (0 on a cache hit;
                             # +1 per sketch-redraw retry)


class SolverService:
    """Shape-class bucketing + batch packing over the padded adaptive engine.

    ``submit`` enqueues; ``flush`` drains every bucket in fixed-size batches
    through one compiled executable per shape class and returns solutions
    keyed by request id. The service is deterministic: request k is solved
    with ``fold_in(base_key, k)`` regardless of what it is packed with;
    padded slots draw from the reserved top-of-range id stream
    ``fold_in(base_key, 2³²−1−slot)`` — disjoint from any realistic
    request id — so a padded slot can never alias a real request's sketch
    (previously every padded slot shared the all-zeros key).

    ``compute_dtype`` (service default, overridable per shape class):
    precision of the engine's one-touch sketch pass — "fp32" / "bf16" /
    "int8" (``kernels.precision``). Certificates (δ̃, Newton decrement)
    are fp32 in every mode; each solution records the mode that produced
    it so callers can audit precision alongside convergence.

    ``mesh``: a ``jax.sharding.Mesh`` turns on the sharded mode — each
    packed batch's A is placed row-sharded over the mesh's data axes and
    the engine runs with ``mesh=`` (the sharded one-touch ladder precompute
    + GSPMD loop, DESIGN.md §5). Every shape class's n must divide by the
    data-shard count; the large-n tail classes only fit devices at all
    this way.
    """

    def __init__(
        self,
        shape_classes: Iterable[ShapeClass] | None = None,
        *,
        batch_size: int = 16,
        method: str = "pcg",
        sketch: str = "gaussian",
        compute_dtype: str = "fp32",
        rho: float = 0.5,
        tol: float = 1e-10,
        max_iters: int = 200,
        seed: int = 0,
        mesh=None,
        strict: bool = True,
        max_retries: int = 2,
        fallback: bool = True,
        flush_deadline_s: float | None = None,
        segment_trips: int = 32,
        checkpoint_dir=None,
        preempt=None,
        ladder_cache: bool = False,
        ladder_cache_size: int = 64,
    ):
        if shape_classes is None:
            # the pod-scale n=65536 tail only exists where the batch is
            # actually sharded; a 1-device service must keep failing fast
            shape_classes = (SHARDED_SHAPE_CLASSES if mesh is not None
                             else DEFAULT_SHAPE_CLASSES)
        self.shape_classes = sorted(shape_classes,
                                    key=lambda c: (c.n, c.d, c.m_max))
        self.batch_size = batch_size
        self.method = method
        self.sketch = sketch
        self.compute_dtype = compute_dtype
        self.rho = rho
        self.tol = tol
        self.max_iters = max_iters
        self.mesh = mesh
        if mesh is not None:
            k = n_data_shards(mesh)
            bad = [c for c in self.shape_classes if c.n % k]
            if bad:
                raise ValueError(
                    f"shape classes {bad} have n not divisible by the "
                    f"mesh's {k} data shards")
        self._base_key = jax.random.PRNGKey(seed)
        self._queues: dict[ShapeClass, list[RidgeRequest]] = {
            c: [] for c in self.shape_classes}
        # GLM traffic buckets by (shape class, family): one Newton-driver
        # batch per family so the objective stays a static jit argument
        self._glm_queues: dict[tuple[ShapeClass, str], list[GLMRequest]] = {}
        # path traffic buckets by (shape class, grid length): requests in a
        # packed path chunk must agree on P (the per-problem grids pack to
        # one (P, B) array); the grids themselves may differ per slot
        self._path_queues: dict[tuple[ShapeClass, int],
                                list[PathRequest]] = {}
        # opt-in λ-free-ladder cache (DESIGN.md §13): fingerprint →
        # (per-slot (L, d, d) level-Gram slice, (d, d) true-Gram slice),
        # LRU-bounded. When on, each slot's sketch keys off the FINGERPRINT
        # (content identity) instead of the request id, so identical
        # repeated data reuses the identical sketch — the cache invariant.
        self.ladder_cache = bool(ladder_cache)
        self.ladder_cache_size = int(ladder_cache_size)
        self._ladder_store: OrderedDict[str, tuple] = OrderedDict()
        self._next_id = 0
        self.newton_iters = 30
        self.newton_tol = 1e-9
        # failure-model knobs (DESIGN.md §9): strict=True raises on invalid
        # data at submit; strict=False quarantines the request and returns a
        # REJECTED solution at flush so one bad tenant cannot crash the
        # caller's whole submit loop. max_retries / fallback parameterize
        # core.robust; flush_deadline_s is the default per-flush budget.
        self.strict = strict
        self.max_retries = max_retries
        self.fallback = fallback
        self.flush_deadline_s = flush_deadline_s
        # preemptible-solve knobs (DESIGN.md §11): segment_trips bounds each
        # engine dispatch so deadlines/preemption bind mid-solve;
        # checkpoint_dir persists per-chunk solver state (deterministic
        # directory names, so a restarted process resumes its chunks);
        # preempt is an ft.PreemptionHandler polled between segments.
        self.segment_trips = segment_trips
        self.checkpoint_dir = checkpoint_dir
        self.preempt = preempt
        self._quarantined: dict[int, "RidgeSolution | GLMSolution"] = {}
        self.rejection_reasons: dict[int, str] = {}
        self.stats = {"requests": 0, "batches": 0, "padded_slots": 0,
                      "solve_seconds": 0.0, "retries": 0, "fallbacks": 0,
                      "rejected": 0, "deadline_exceeded": 0,
                      "segments": 0, "resumed_chunks": 0,
                      "path_requests": 0, "ladder_cache_hits": 0,
                      "ladder_cache_misses": 0, "sketch_passes_saved": 0}

    def slot_utilization(self) -> float:
        """Fraction of solved batch slots that held a real request."""
        total = self.stats["batches"] * self.batch_size
        if not total:
            return 1.0
        return 1.0 - self.stats["padded_slots"] / total

    # -- bucketing ---------------------------------------------------------
    def bucket_for(self, n: int, d: int) -> ShapeClass:
        """Smallest configured shape class that fits an (n, d) request."""
        for c in self.shape_classes:
            if n <= c.n and d <= c.d:
                return c
        raise ValueError(
            f"no shape class fits (n={n}, d={d}); "
            f"largest is {self.shape_classes[-1]}")

    def submit(self, A, y, nu, lam_diag=None, *,
               deadline_s: float | None = None) -> int:
        """Enqueue one ridge problem; returns its request id.

        ``deadline_s``: per-request wall-clock budget, counted from submit.
        Urgent requests are dispatched earliest-deadline-first at flush,
        and the deadline binds MID-solve (the segmented engine): a request
        that runs out of time returns its best finite iterate, its real δ̃
        and an honest ``DEADLINE_EXCEEDED``; one whose budget is already
        spent before its chunk dispatches returns x = 0 with no
        certificate.

        ν must be a positive finite float: the service pads requests to the
        class shape with zero A-columns and Λ = 1 on padded coordinates, so
        H restricted to the padded block is ν²·I — with ν = 0 that block is
        singular, its Cholesky is NaN, and the NaN silently poisons the
        problem's solution AND its δ̃/m_final certificates (no exception is
        ever raised inside the jitted engine). The same argument applies to
        NaN/Inf entries in A, y or Λ — submit is the only place the failure
        is observable before it becomes a wrong answer, so admission
        validates all of them: ``strict=True`` raises naming the request,
        ``strict=False`` quarantines it into a ``REJECTED`` solution at
        flush (the engine guards remain the backstop either way).
        """
        A = jnp.asarray(A)
        y = jnp.asarray(y)
        cls = self.bucket_for(*A.shape)     # shape errors always raise
        nu, reason = self._validate(A, y, nu, lam_diag)
        rid = self._next_id
        self._next_id += 1
        self.stats["requests"] += 1
        if reason is not None:
            self._reject(rid, reason, RidgeSolution(
                req_id=rid, x=jnp.zeros((A.shape[1],), A.dtype),
                delta_tilde=float("nan"), m_final=0, iters=0, doublings=0,
                shape_class=cls, batch_index=-1, sketch=cls.sketch or
                self.sketch,
                compute_dtype=cls.compute_dtype or self.compute_dtype,
                status=SolveStatus.REJECTED.name,
                converged=False))
            return rid
        deadline = (None if deadline_s is None
                    else time.perf_counter() + float(deadline_s))
        self._queues[cls].append(RidgeRequest(
            req_id=rid, A=A, y=y, nu=nu, lam_diag=lam_diag,
            deadline=deadline))
        return rid

    def _validate(self, A, y, nu, lam_diag) -> tuple[float, str | None]:
        """Admission checks beyond shape. Returns (ν, reason); reason is
        None iff admissible. In strict mode an inadmissible request raises
        a ValueError naming the request id it would have been assigned."""
        import numpy as np

        reason = None
        try:
            nu = self._check_nu(nu)
        except ValueError as e:
            reason = str(e)
            nu = float("nan")
        if reason is None and y.shape != (A.shape[0],):
            # malformed geometry is a caller bug, not bad data: always raise
            raise ValueError(
                f"y has shape {y.shape}, expected ({A.shape[0]},) to match A")
        if reason is None and not bool(np.all(np.isfinite(np.asarray(A)))):
            reason = "non-finite entries in A"
        if reason is None and not bool(np.all(np.isfinite(np.asarray(y)))):
            reason = "non-finite entries in y"
        if reason is None and lam_diag is not None and not bool(
                np.all(np.isfinite(np.asarray(lam_diag)))):
            reason = "non-finite entries in lam_diag"
        if reason is not None and self.strict:
            raise ValueError(
                f"request {self._next_id} rejected: {reason}")
        return nu, reason

    def _reject(self, rid: int, reason: str, solution) -> None:
        """Quarantine an inadmissible request (strict=False): it never
        touches a packed batch and comes back REJECTED at flush."""
        self._quarantined[rid] = solution
        self.rejection_reasons[rid] = reason
        self.stats["rejected"] += 1

    @staticmethod
    def _check_nu(nu) -> float:
        nu = float(nu)
        if not math.isfinite(nu) or nu <= 0.0:
            raise ValueError(
                f"nu must be a positive finite float, got {nu!r}: padded "
                "coordinates carry H = ν²·I, so ν = 0 makes the padded "
                "block singular and NaN-poisons the certificates")
        return nu

    def submit_glm(self, A, y, nu, family: str = "logistic",
                   lam_diag=None, *, deadline_s: float | None = None) -> int:
        """Enqueue one regularized GLM problem (``family``: logistic /
        poisson / huber[:delta]); returns its request id.

        Padding is the same block-diagonal argument as ridge: padded
        COLUMNS never enter the loss (A-columns are zero) and carry
        ν²Λ = ν²·I, so their optimum is exactly 0 and the solution
        restricted to the request's coordinates is unchanged; padded ROWS
        are all-zero data rows whose loss term ℓ(0, 0) is a constant —
        zero gradient, zero Hessian weight contribution.

        Admission validation mirrors ``submit`` (finiteness of A/y/Λ and
        ν > 0; strict raise vs quarantine), as does ``deadline_s`` (EDF
        dispatch; the budget binds between the Newton driver's outer
        steps)."""
        get_objective(family)          # validate the family name up front
        A = jnp.asarray(A)
        y = jnp.asarray(y)
        cls = self.bucket_for(*A.shape)     # shape errors always raise
        nu, reason = self._validate(A, y, nu, lam_diag)
        rid = self._next_id
        self._next_id += 1
        self.stats["requests"] += 1
        if reason is not None:
            self._reject(rid, reason, GLMSolution(
                req_id=rid, x=jnp.zeros((A.shape[1],), A.dtype),
                family=family, decrement=float("nan"), converged=False,
                newton_iters=0, m_trajectory=(), m_final=0, inner_iters=0,
                shape_class=cls, batch_index=-1,
                sketch=cls.sketch or self.sketch,
                compute_dtype=cls.compute_dtype or self.compute_dtype,
                status=SolveStatus.REJECTED.name))
            return rid
        deadline = (None if deadline_s is None
                    else time.perf_counter() + float(deadline_s))
        req = GLMRequest(req_id=rid, A=A, y=y, nu=nu,
                         family=family, lam_diag=lam_diag, deadline=deadline)
        self._glm_queues.setdefault((cls, family), []).append(req)
        return rid

    def submit_path(self, A, y, nus, lam_diag=None, *,
                    deadline_s: float | None = None) -> int:
        """Enqueue one ridge problem against a λ GRID; returns its request
        id. The flush returns a ``PathSolution`` whose per-λ ``PathPoint``s
        each carry the full δ̃/m/status certificate.

        ``nus`` is the grid of ν values, walked in the given order with x
        and the sketch level warm-started point-to-point — sort it
        strong→weak regularization so warm starts move downhill. The whole
        grid is solved off ONE one-touch sketch pass (the ladder-level
        Grams are λ-free — DESIGN.md §13); requests with equal grid
        lengths pack into one chunk even when their grids differ.

        Admission validates what ``submit`` validates, for EVERY grid
        point's ν (each λ point pads the problem to the class shape, so a
        single ν = 0 in the grid would NaN-poison that point)."""
        import numpy as np

        A = jnp.asarray(A)
        y = jnp.asarray(y)
        cls = self.bucket_for(*A.shape)     # shape errors always raise
        nus = tuple(float(v) for v in np.ravel(np.asarray(nus)))
        if not nus:
            raise ValueError("submit_path needs a non-empty λ grid")
        reason = None
        try:
            for v in nus:
                self._check_nu(v)
        except ValueError as e:
            reason = str(e)
            if self.strict:
                raise ValueError(
                    f"request {self._next_id} rejected: {reason}") from e
        if reason is None:
            _, reason = self._validate(A, y, nus[0], lam_diag)
        rid = self._next_id
        self._next_id += 1
        self.stats["requests"] += 1
        self.stats["path_requests"] += 1
        sketch = cls.sketch or self.sketch
        cd = cls.compute_dtype or self.compute_dtype
        if reason is not None:
            zero = jnp.zeros((A.shape[1],), A.dtype)
            pts = tuple(PathPoint(
                nu=v, x=zero, delta_tilde=float("nan"), m_final=0, iters=0,
                doublings=0, status=SolveStatus.REJECTED.name,
                converged=False) for v in nus)
            self._reject(rid, reason, PathSolution(
                req_id=rid, points=pts, shape_class=cls, batch_index=-1,
                sketch=sketch, compute_dtype=cd,
                status=SolveStatus.REJECTED.name, converged=False,
                sketch_passes=0))
            return rid
        deadline = (None if deadline_s is None
                    else time.perf_counter() + float(deadline_s))
        self._path_queues.setdefault((cls, len(nus)), []).append(PathRequest(
            req_id=rid, A=A, y=y, nus=nus, lam_diag=lam_diag,
            deadline=deadline))
        return rid

    # -- packing -----------------------------------------------------------
    def _pack(self, cls: ShapeClass, reqs: list[RidgeRequest],
              slot_ids: list[int] | None = None):
        """Pad each request to the class shape and stack; pad the batch to
        ``batch_size`` with trivial (b = 0) problems.

        Staged in host numpy buffers (in-place writes) with ONE device
        transfer per field — out-of-jit `.at[i].set` would copy the full
        padded batch buffer once per request. Per-slot keys are one vmapped
        ``fold_in`` over the slot-id vector (real slots: req_id; padded
        slots: the reserved top-of-range id 2³²−1−slot, so padding never
        aliases a real request's sketch) — no per-request host↔device
        round trips.

        ``slot_ids`` overrides the real slots' key ids (the ladder cache
        keys slots by content fingerprint instead of request id, so
        identical data draws the identical sketch)."""
        import numpy as np

        B = self.batch_size
        dtype = np.dtype(reqs[0].A.dtype)
        A = np.zeros((B, cls.n, cls.d), dtype)
        b = np.zeros((B, cls.d), dtype)
        nu = np.ones((B,), dtype)
        lam = np.ones((B, cls.d), dtype)
        for i, r in enumerate(reqs):
            ni, di = r.A.shape
            A[i, :ni, :di] = np.asarray(r.A, dtype)
            b[i, :di] = np.asarray(r.A.T @ r.y, dtype)
            nu[i] = r.nu
            if r.lam_diag is not None:
                lam[i, :di] = np.asarray(r.lam_diag, dtype)
        real_ids = ([r.req_id for r in reqs] if slot_ids is None
                    else list(slot_ids))
        slot_ids = jnp.asarray(
            real_ids + [0xFFFFFFFF - s for s in range(len(reqs), B)],
            jnp.uint32)
        keys = jax.vmap(
            lambda i: jax.random.fold_in(self._base_key, i))(slot_ids)
        q = Quadratic(A=jnp.asarray(A), b=jnp.asarray(b), nu=jnp.asarray(nu),
                      lam_diag=jnp.asarray(lam), batched=True)
        if self.mesh is not None:
            q = shard_quadratic(q, self.mesh)
        return q, keys

    def _pack_glm(self, cls: ShapeClass, reqs: list[GLMRequest]):
        """Pad each GLM request to the class shape and stack (A, y, ν, Λ);
        empty slots are all-zero problems (x = 0 is optimal, decrement 0 ⇒
        the Newton driver freezes them at step one). Same staging + key
        scheme as ``_pack``."""
        import numpy as np

        B = self.batch_size
        dtype = np.dtype(reqs[0].A.dtype)
        A = np.zeros((B, cls.n, cls.d), dtype)
        y = np.zeros((B, cls.n), dtype)
        nu = np.ones((B,), dtype)
        lam = np.ones((B, cls.d), dtype)
        for i, r in enumerate(reqs):
            ni, di = r.A.shape
            A[i, :ni, :di] = np.asarray(r.A, dtype)
            y[i, :ni] = np.asarray(r.y, dtype)
            nu[i] = r.nu
            if r.lam_diag is not None:
                lam[i, :di] = np.asarray(r.lam_diag, dtype)
        slot_ids = jnp.asarray(
            [r.req_id for r in reqs]
            + [0xFFFFFFFF - s for s in range(len(reqs), B)], jnp.uint32)
        keys = jax.vmap(
            lambda i: jax.random.fold_in(self._base_key, i))(slot_ids)
        return (jnp.asarray(A), jnp.asarray(y), jnp.asarray(nu),
                jnp.asarray(lam), keys)

    # -- solving -----------------------------------------------------------
    def flush(self, deadline_s: float | None = None
              ) -> "dict[int, RidgeSolution | GLMSolution]":
        """Solve everything queued; returns {req_id: solution} (ridge and
        GLM requests come back in one map, each with its certificate type).

        ``deadline_s`` (default: the service's ``flush_deadline_s``) is a
        per-flush wall-clock budget. Chunks dispatch **earliest-deadline-
        first**: within each queue requests sort by their per-request
        deadline (undeadlined last, insertion order preserved), and across
        queues the chunk with the most urgent member goes first — a
        just-submitted urgent request is no longer stuck behind a backlog
        of patient ones. Each dispatched chunk gets the minimum of the
        remaining flush budget and its most urgent member's remaining
        budget, and the deadline binds MID-solve through the segmented
        engine (``DESIGN.md §11``): requests that run out of time come back
        with their best finite iterate, its real δ̃, and an honest
        ``DEADLINE_EXCEEDED``. A chunk whose budget is already spent
        before dispatch is expired wholesale (x = 0, no certificate).
        Quarantined (REJECTED) requests are always returned first; they
        cost no solve time.

        With ``checkpoint_dir``/``preempt`` set, each chunk solve
        checkpoints between segments and a SIGTERM raises
        ``core.PreemptedError`` out of flush after committing state; a
        restarted service that receives the SAME submissions (ids and
        problems — the deterministic replay contract) resumes each chunk
        from its last committed segment.
        """
        if deadline_s is None:
            deadline_s = self.flush_deadline_s
        t0 = time.perf_counter()
        out: dict[int, RidgeSolution | GLMSolution] = {}
        out.update(self._quarantined)
        self._quarantined = {}

        def edf(queue):
            # stable: deadlined requests first by deadline, rest in
            # insertion order
            return sorted(queue, key=lambda r: (r.deadline is None,
                                                r.deadline or 0.0))

        # (urgency, seq, cls, family|None, chunk) — family=None ⇒ ridge
        chunks = []
        seq = 0
        for cls in self.shape_classes:
            queue, self._queues[cls] = self._queues[cls], []
            queue = edf(queue)
            for i in range(0, len(queue), self.batch_size):
                chunk = queue[i: i + self.batch_size]
                dl = [r.deadline for r in chunk if r.deadline is not None]
                chunks.append((min(dl) if dl else None, seq, cls, None, chunk))
                seq += 1
        for (cls, family), queue in list(self._glm_queues.items()):
            self._glm_queues[(cls, family)] = []
            queue = edf(queue)
            for i in range(0, len(queue), self.batch_size):
                chunk = queue[i: i + self.batch_size]
                dl = [r.deadline for r in chunk if r.deadline is not None]
                chunks.append((min(dl) if dl else None, seq, cls, family,
                               chunk))
                seq += 1
        # path chunks carry kind=("path", P); budgets bind whole-chunk
        # (expire-before-dispatch), not mid-solve
        for (cls, P), queue in list(self._path_queues.items()):
            self._path_queues[(cls, P)] = []
            queue = edf(queue)
            for i in range(0, len(queue), self.batch_size):
                chunk = queue[i: i + self.batch_size]
                dl = [r.deadline for r in chunk if r.deadline is not None]
                chunks.append((min(dl) if dl else None, seq, cls,
                               ("path", P), chunk))
                seq += 1
        chunks.sort(key=lambda c: (c[0] is None, c[0] or 0.0, c[1]))

        for chunk_deadline, _, cls, family, chunk in chunks:
            now = time.perf_counter()
            budgets = []
            if deadline_s is not None:
                budgets.append(deadline_s - (now - t0))
            if chunk_deadline is not None:
                budgets.append(chunk_deadline - now)
            budget = min(budgets) if budgets else None
            if budget is not None and budget <= 0:
                out.update(self._expire_chunk(cls, chunk, family=family))
            elif family is None:
                out.update(self._solve_chunk(cls, chunk, budget_s=budget))
            elif isinstance(family, tuple):
                out.update(self._solve_path_chunk(cls, chunk))
            else:
                out.update(self._solve_glm_chunk(cls, family, chunk,
                                                 budget_s=budget))
        return out

    def _chunk_checkpoint(self, cls: ShapeClass, reqs,
                          family: str | None = None):
        """Per-chunk CheckpointManager under ``checkpoint_dir``, with a
        DETERMINISTIC directory name derived from the chunk's membership —
        a restarted process that replays the same submissions re-derives
        the same directory and resumes the committed state."""
        if self.checkpoint_dir is None:
            return None
        import hashlib
        from pathlib import Path

        from repro.ft.checkpoint import CheckpointManager

        ids = ",".join(str(r.req_id) for r in reqs)
        token = f"{cls.n}x{cls.d}x{cls.m_max}:{family or 'ridge'}:{ids}"
        tag = hashlib.sha1(token.encode()).hexdigest()[:12]
        return CheckpointManager(Path(self.checkpoint_dir) / f"chunk_{tag}")

    def _expire_chunk(self, cls: ShapeClass, reqs, family: str | None = None):
        """DEADLINE_EXCEEDED solutions for an undispatched chunk."""
        out = {}
        name = SolveStatus.DEADLINE_EXCEEDED.name
        sketch = cls.sketch or self.sketch
        cd = cls.compute_dtype or self.compute_dtype
        for r in reqs:
            zero = jnp.zeros((r.A.shape[1],), r.A.dtype)
            if family is None:
                out[r.req_id] = RidgeSolution(
                    req_id=r.req_id, x=zero, delta_tilde=float("nan"),
                    m_final=0, iters=0, doublings=0, shape_class=cls,
                    batch_index=-1, sketch=sketch, compute_dtype=cd,
                    status=name, converged=False)
            elif isinstance(family, tuple):
                pts = tuple(PathPoint(
                    nu=v, x=zero, delta_tilde=float("nan"), m_final=0,
                    iters=0, doublings=0, status=name, converged=False)
                    for v in r.nus)
                out[r.req_id] = PathSolution(
                    req_id=r.req_id, points=pts, shape_class=cls,
                    batch_index=-1, sketch=sketch, compute_dtype=cd,
                    status=name, converged=False, sketch_passes=0)
            else:
                out[r.req_id] = GLMSolution(
                    req_id=r.req_id, x=zero, family=family,
                    decrement=float("nan"), converged=False, newton_iters=0,
                    m_trajectory=(), m_final=0, inner_iters=0,
                    shape_class=cls, batch_index=-1, sketch=sketch,
                    compute_dtype=cd, status=name)
            self.stats["deadline_exceeded"] += 1
        return out

    # -- λ-free ladder cache (DESIGN.md §13) -------------------------------
    def _ladder_fingerprint(self, A, lam_diag, cls: ShapeClass,
                            sketch: str, cd: str) -> str:
        """Content identity of a slot's λ-free ladder: the data, the
        regularizer GEOMETRY (Λ — not ν: the level Grams are λ-free), the
        class shape/budget, the sketch family and the sketch-pass
        precision. Everything that determines the (L, d, d) gram slice
        given the fingerprint-derived slot key."""
        import hashlib

        import numpy as np

        h = hashlib.sha1()
        h.update(f"{cls.n}x{cls.d}x{cls.m_max}:{sketch}:{cd}:".encode())
        h.update(np.ascontiguousarray(np.asarray(A)).tobytes())
        h.update(b"|lam:")
        if lam_diag is not None:
            h.update(np.ascontiguousarray(np.asarray(lam_diag)).tobytes())
        return h.hexdigest()

    @staticmethod
    def _fp_slot_id(fp: str) -> int:
        """Sketch-key id for a fingerprinted slot (the cache invariant:
        identical content ⇒ identical sketch). Bit 31 is cleared so the
        id stream stays disjoint from the padded slots' reserved
        top-of-range ids."""
        return int(fp[:8], 16) & 0x7FFFFFFF

    def _ladder_assets(self, cls: ShapeClass, fps: list[str], q, keys,
                       sketch: str, cd: str):
        """Serve a chunk's λ-free ladder through the fingerprint cache.

        All real slots cached ⇒ assemble the (L, B, d, d) ladder and the
        (B, d, d) true Gram from the stored per-slot slices — the chunk
        SKIPS its sketch pass entirely (padded slots have A = 0 ⇒ zero
        Grams). Any miss ⇒ run the one-touch pass ONCE for the whole
        chunk (``prepare_path_ladder``) and cache the new slices.
        Returns ``(grams, gram_full, skipped)``."""
        import numpy as np

        B = self.batch_size
        L = len(doubling_ladder(cls.m_max))
        hits = [fp in self._ladder_store for fp in fps]
        if all(hits):
            dt = np.dtype(np.asarray(q.b).dtype)
            grams = np.zeros((L, B, cls.d, cls.d), dt)
            gfull = np.zeros((B, cls.d, cls.d), dt)
            for i, fp in enumerate(fps):
                g, f = self._ladder_store[fp]
                self._ladder_store.move_to_end(fp)
                grams[:, i] = g
                gfull[i] = f
            self.stats["ladder_cache_hits"] += len(fps)
            self.stats["sketch_passes_saved"] += 1
            return jnp.asarray(grams), jnp.asarray(gfull), True
        grams, gfull = prepare_path_ladder(
            q, keys, m_max=cls.m_max, sketch=sketch, gram_hvp=True,
            mesh=self.mesh, compute_dtype=cd)
        gn, fn = np.asarray(grams), np.asarray(gfull)
        for i, (fp, hit) in enumerate(zip(fps, hits)):
            if hit:
                self.stats["ladder_cache_hits"] += 1
                self._ladder_store.move_to_end(fp)
            else:
                self.stats["ladder_cache_misses"] += 1
                self._ladder_store[fp] = (gn[:, i].copy(), fn[i].copy())
        while len(self._ladder_store) > self.ladder_cache_size:
            self._ladder_store.popitem(last=False)
        return grams, gfull, False

    def _solve_path_chunk(self, cls: ShapeClass, reqs: list[PathRequest]):
        """One packed λ-grid chunk: ONE shared λ-free ladder (from the
        cache or one one-touch pass) + per-point warm-started robust
        solves (``core.robust.robust_path_solve_batched``)."""
        import numpy as np

        P = len(reqs[0].nus)
        sketch = cls.sketch or self.sketch
        cd = cls.compute_dtype or self.compute_dtype
        # ride the ridge packer: the packed ν is a placeholder (the path
        # engine reads the (P, B) grid, never q.nu)
        proxies = [RidgeRequest(req_id=r.req_id, A=r.A, y=r.y, nu=1.0,
                                lam_diag=r.lam_diag, deadline=r.deadline)
                   for r in reqs]
        fps = None
        if self.ladder_cache:
            fps = [self._ladder_fingerprint(r.A, r.lam_diag, cls, sketch, cd)
                   for r in reqs]
            q, keys = self._pack(cls, proxies,
                                 slot_ids=[self._fp_slot_id(f) for f in fps])
        else:
            q, keys = self._pack(cls, proxies)
        nus = np.ones((P, self.batch_size),
                      np.dtype(np.asarray(q.b).dtype))
        for i, r in enumerate(reqs):
            nus[:, i] = r.nus
        grams = gfull = None
        skipped = False
        if self.ladder_cache:
            grams, gfull, skipped = self._ladder_assets(
                cls, fps, q, keys, sketch, cd)
        t0 = time.perf_counter()
        xs, stats = robust_path_solve_batched(
            q, keys, jnp.asarray(nus), m_max=cls.m_max, method=self.method,
            sketch=sketch, max_iters=self.max_iters, rho=self.rho,
            tol=self.tol, mesh=self.mesh, max_retries=self.max_retries,
            fallback=self.fallback, compute_dtype=cd,
            grams=grams, gram_full=gfull)
        xs = jax.block_until_ready(xs)
        self.stats["solve_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["padded_slots"] += self.batch_size - len(reqs)
        passes = int(stats["sketch_passes"]) - (1 if skipped else 0)
        out = {}
        for i, r in enumerate(reqs):
            di = r.A.shape[1]
            pts = []
            for p in range(P):
                self.stats["retries"] += int(stats["retries"][p, i])
                self.stats["fallbacks"] += int(stats["fell_back"][p, i])
                pts.append(PathPoint(
                    nu=r.nus[p],
                    x=xs[p, i, :di],
                    delta_tilde=float(stats["dtilde"][p, i]),
                    m_final=int(stats["m_final"][p, i]),
                    iters=int(stats["iters"][p, i]),
                    doublings=int(stats["doublings"][p, i]),
                    status=status_name(stats["status"][p, i]),
                    converged=bool(stats["converged"][p, i]),
                    retries=int(stats["retries"][p, i]),
                    fell_back=bool(stats["fell_back"][p, i]),
                ))
            bad = [pt for pt in pts if not pt.converged]
            out[r.req_id] = PathSolution(
                req_id=r.req_id, points=tuple(pts), shape_class=cls,
                batch_index=i, sketch=sketch, compute_dtype=cd,
                status=bad[0].status if bad else "OK",
                converged=not bad, cache_hit=skipped,
                sketch_passes=passes)
        return out

    def _solve_glm_chunk(self, cls: ShapeClass, family: str,
                         reqs: list[GLMRequest],
                         budget_s: float | None = None):
        A, y, nu, lam, keys = self._pack_glm(cls, reqs)
        sketch = cls.sketch or self.sketch
        cd = cls.compute_dtype or self.compute_dtype
        t0 = time.perf_counter()
        x, stats = adaptive_newton_solve_batched(
            family, A, y, nu, lam_diag=lam, keys=keys, m_max=cls.m_max,
            method=self.method, sketch=sketch,
            newton_iters=self.newton_iters, tol=self.newton_tol,
            inner_max_iters=self.max_iters, rho=self.rho,
            inner_tol=self.tol, mesh=self.mesh, compute_dtype=cd,
            deadline_s=budget_s)
        x = jax.block_until_ready(x)
        self.stats["solve_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["padded_slots"] += self.batch_size - len(reqs)
        out = {}
        m_traj = stats["m_trajectory"]                       # (T, B)
        for i, r in enumerate(reqs):
            di = r.A.shape[1]
            traj = tuple(int(m) for m in m_traj[:, i] if m > 0)
            if int(stats["status"][i]) == int(SolveStatus.DEADLINE_EXCEEDED):
                self.stats["deadline_exceeded"] += 1
            out[r.req_id] = GLMSolution(
                req_id=r.req_id,
                x=x[i, :di],
                family=family,
                decrement=float(stats["decrement"][i]),
                converged=bool(stats["converged"][i]),
                newton_iters=int(stats["newton_iters"][i]),
                m_trajectory=traj,
                m_final=int(stats["m_final"][i]),
                inner_iters=int(stats["inner_iters"][i]),
                shape_class=cls,
                batch_index=i,
                sketch=sketch,
                compute_dtype=cd,
                status=status_name(stats["status"][i]),
                stalled=bool(stats["stalled"][i]),
            )
        return out

    def _solve_chunk(self, cls: ShapeClass, reqs: list[RidgeRequest],
                     budget_s: float | None = None):
        sketch = cls.sketch or self.sketch
        cd = cls.compute_dtype or self.compute_dtype
        grams = gfull = None
        skipped = False
        if self.ladder_cache:
            fps = [self._ladder_fingerprint(r.A, r.lam_diag, cls, sketch, cd)
                   for r in reqs]
            q, keys = self._pack(cls, reqs,
                                 slot_ids=[self._fp_slot_id(f) for f in fps])
            grams, gfull, skipped = self._ladder_assets(
                cls, fps, q, keys, sketch, cd)
        else:
            q, keys = self._pack(cls, reqs)
        t0 = time.perf_counter()
        # the robust driver = guarded engine + per-problem sketch-redraw
        # retries + direct_solve degradation; a quarantine-evading fault
        # (e.g. numerically degenerate but finite data) still ends in a
        # finite answer with an honest verdict, isolated to its slot.
        # Any preemptibility knob (budget / checkpoint / SIGTERM handler)
        # routes the solve through the segmented driver; with none set the
        # call — and its numbers — are the single-dispatch ones.
        seg_kwargs = {}
        if (budget_s is not None or self.checkpoint_dir is not None
                or self.preempt is not None):
            seg_kwargs = dict(
                deadline_s=budget_s,
                segment_trips=self.segment_trips,
                checkpoint=self._chunk_checkpoint(cls, reqs),
                preempt=self.preempt,
            )
        x, stats = robust_padded_solve_batched(
            q, keys, m_max=cls.m_max, method=self.method, sketch=sketch,
            max_iters=self.max_iters, rho=self.rho, tol=self.tol,
            mesh=self.mesh, max_retries=self.max_retries,
            fallback=self.fallback, compute_dtype=cd,
            grams=grams, gram_full=gfull, **seg_kwargs)
        x = jax.block_until_ready(x)
        self.stats["solve_seconds"] += time.perf_counter() - t0
        self.stats["batches"] += 1
        self.stats["padded_slots"] += self.batch_size - len(reqs)
        self.stats["segments"] += int(stats.get("segments", 0))
        self.stats["resumed_chunks"] += int(bool(stats.get("resumed", False)))
        out = {}
        for i, r in enumerate(reqs):
            di = r.A.shape[1]
            self.stats["retries"] += int(stats["retries"][i])
            self.stats["fallbacks"] += int(stats["fell_back"][i])
            if int(stats["status"][i]) == int(SolveStatus.DEADLINE_EXCEEDED):
                self.stats["deadline_exceeded"] += 1
            out[r.req_id] = RidgeSolution(
                req_id=r.req_id,
                x=x[i, :di],
                delta_tilde=float(stats["dtilde"][i]),
                m_final=int(stats["m_final"][i]),
                iters=int(stats["iters"][i]),
                doublings=int(stats["doublings"][i]),
                shape_class=cls,
                batch_index=i,
                sketch=sketch,
                compute_dtype=cd,
                status=status_name(stats["status"][i]),
                converged=bool(stats["converged"][i]),
                stalled=bool(stats["stalled"][i]),
                retries=int(stats["retries"][i]),
                fell_back=bool(stats["fell_back"][i]),
                cache_hit=skipped,
            )
        return out

    def solve_one(self, A, y, nu, lam_diag=None) -> RidgeSolution:
        """Convenience: submit + flush a single request (still batched —
        the padded slots ride along as no-op problems)."""
        rid = self.submit(A, y, nu, lam_diag)
        return self.flush()[rid]
