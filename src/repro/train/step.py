"""Training step: masked CE + z-loss, microbatched grad accumulation,
remat, AdamW, mixed precision. Built to be lowered under a mesh with the
shardings from ``repro.dist.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import forward
from repro.models.config import ModelConfig
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    z_loss: float = 1e-4
    num_microbatches: int = 1
    compute_dtype: Any = jnp.bfloat16
    remat: bool = True
    scan_unroll: bool = False  # analysis builds (see models.transformer)
    ce_chunks: int = 0         # >0: blocked cross-entropy — never
                               # materialize (B,S,V) logits; stream
                               # logsumexp over vocab chunks with remat
                               # (§Perf Cell B follow-up)


def lm_loss(params, cfg: ModelConfig, tokens, labels, mask, *,
            enc_feats=None, z_loss: float = 1e-4,
            compute_dtype=jnp.bfloat16, remat: bool = True,
            scan_unroll: bool = False):
    """Next-token CE with optional z-loss. labels/mask: (B, S)."""
    logits, _ = forward(
        params, cfg, tokens, enc_feats=enc_feats,
        compute_dtype=compute_dtype, remat=remat, scan_unroll=scan_unroll,
    )
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0] - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    zl = z_loss * jnp.sum(jnp.square(lse) * mask) / denom if z_loss else 0.0
    return ce + zl, {"ce": ce, "tokens": denom}


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    batch: {"tokens": (B,S) int32, "labels": (B,S) int32,
            "mask": (B,S) f32, ["enc_feats"]: (B,E,D)}.
    Grad accumulation over ``num_microbatches`` via lax.scan (batch is split
    on the leading axis; per-microbatch remat keeps live memory bounded).
    """

    def loss_fn(params, mb):
        if tcfg.ce_chunks:
            return blocked_lm_loss(
                params, cfg, mb["tokens"], mb["labels"], mb["mask"],
                ce_chunks=tcfg.ce_chunks, enc_feats=mb.get("enc_feats"),
                z_loss=tcfg.z_loss, compute_dtype=tcfg.compute_dtype,
                remat=tcfg.remat, scan_unroll=tcfg.scan_unroll,
            )
        return lm_loss(
            params, cfg, mb["tokens"], mb["labels"], mb["mask"],
            enc_feats=mb.get("enc_feats"),
            z_loss=tcfg.z_loss, compute_dtype=tcfg.compute_dtype,
            remat=tcfg.remat, scan_unroll=tcfg.scan_unroll,
        )

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: OptState, batch):
        nmb = tcfg.num_microbatches
        if nmb > 1:
            batch_r = jax.tree.map(
                lambda x: x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:]),
                batch,
            )

            def body(acc, mb):
                (loss, aux), g = grad_fn(params, mb)
                acc = jax.tree.map(jnp.add, acc, {"g": g, "loss": loss,
                                                  "ce": aux["ce"]})
                return acc, None

            zero = {
                "g": jax.tree.map(
                    lambda p: jnp.zeros(p.shape, jnp.float32), params
                ),
                "loss": jnp.zeros((), jnp.float32),
                "ce": jnp.zeros((), jnp.float32),
            }
            acc, _ = jax.lax.scan(
                body, zero, batch_r, unroll=nmb if tcfg.scan_unroll else 1
            )
            grads = jax.tree.map(lambda g: g / nmb, acc["g"])
            loss = acc["loss"] / nmb
            ce = acc["ce"] / nmb
        else:
            (loss, aux), grads = grad_fn(params, batch)
            ce = aux["ce"]

        params, opt_state, om = adamw_update(tcfg.opt, params, grads, opt_state)
        metrics = {"loss": loss, "ce": ce, **om}
        return params, opt_state, metrics

    return train_step


__all__ = [
    "TrainConfig",
    "AdamWConfig",
    "OptState",
    "init_opt_state",
    "lm_loss",
    "make_train_step",
]


# ---------------------------------------------------------------------------
# Blocked cross-entropy (memory-roofline optimization, EXPERIMENTS §Perf B4)
# ---------------------------------------------------------------------------

def blocked_lm_loss(params, cfg: ModelConfig, tokens, labels, mask, *,
                    ce_chunks: int, enc_feats=None, z_loss: float = 1e-4,
                    compute_dtype=jnp.bfloat16, remat: bool = True,
                    scan_unroll: bool = False):
    """CE + z-loss WITHOUT materializing (B, S, V) logits.

    The final hidden states x (B,S,D) are produced once; the vocab dim is
    processed in ``ce_chunks`` chunks with a streaming logsumexp and a
    rematerialized chunk body, so peak logits memory drops by the chunk
    factor (the backward pass recomputes each chunk's logits). The chunk
    count should divide the vocab; with vocab sharded over `model`, chunk
    boundaries align with shard boundaries when ce_chunks % TP == 0.
    """
    from repro.models import transformer as T
    from repro.models import layers as L

    B, S = tokens.shape
    # forward to final hidden states (logits path bypassed)
    x = T.embed_tokens(params, cfg, tokens, compute_dtype)
    positions = jnp.arange(S)
    enc_out = None
    if cfg.n_enc_layers and enc_feats is not None:
        enc_out = T.encode(params, cfg, enc_feats, compute_dtype)
    for i, kind in enumerate(cfg.pattern):
        name = f"p{i}_{kind}"
        if cfg.n_blocks == 0:
            continue

        def body(x, xs, kind=kind):
            bp, _ = xs
            fn = T.apply_layer
            if remat:
                fn = jax.checkpoint(T.apply_layer, static_argnums=(1, 2))
            x, _ = fn(bp, cfg, kind, x, positions, None, None, enc_out)
            return x, None

        x, _ = jax.lax.scan(
            body, x, (params["blocks"][name], None),
            unroll=cfg.n_blocks if scan_unroll else 1,
        )
    for i in range(cfg.n_rem):
        kind = cfg.pattern[i]
        rp = params["rem"][f"r{i}_{kind}"]
        x, _ = T.apply_layer(rp, cfg, kind, x, positions, None, None, enc_out)
    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)

    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    V = head.shape[1]
    nc = ce_chunks
    if V % nc:
        raise ValueError(f"vocab {V} not divisible by ce_chunks {nc}")
    Vc = V // nc
    head_r = head.reshape(cfg.d_model, nc, Vc).transpose(1, 0, 2)  # (nc,D,Vc)

    def chunk_body(carry, inp):
        run_max, run_sum, tgt = carry
        w_c, c_idx = inp
        logits_c = (x @ w_c.astype(compute_dtype)).astype(jnp.float32)
        logits_c = L.softcap(logits_c, cfg.final_softcap)
        m_c = jnp.max(logits_c, axis=-1)
        new_max = jnp.maximum(run_max, m_c)
        run_sum = run_sum * jnp.exp(run_max - new_max) + jnp.sum(
            jnp.exp(logits_c - new_max[..., None]), axis=-1
        )
        # target logit if the label falls in this chunk
        local = labels - c_idx * Vc
        in_chunk = (local >= 0) & (local < Vc)
        li = jnp.take_along_axis(
            logits_c, jnp.clip(local, 0, Vc - 1)[..., None], axis=-1
        )[..., 0]
        tgt = tgt + jnp.where(in_chunk, li, 0.0)
        return (new_max, run_sum, tgt), None

    init = (
        jnp.full((B, S), -jnp.inf, jnp.float32),
        jnp.zeros((B, S), jnp.float32),
        jnp.zeros((B, S), jnp.float32),
    )
    (mx, sm, tgt), _ = jax.lax.scan(
        jax.checkpoint(chunk_body) if remat else chunk_body,
        init, (head_r, jnp.arange(nc)),
        unroll=nc if scan_unroll else 1,
    )
    lse = mx + jnp.log(sm)
    ll = tgt - lse
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    ce = -jnp.sum(ll * mask) / denom
    zl = z_loss * jnp.sum(jnp.square(lse) * mask) / denom if z_loss else 0.0
    return ce + zl, {"ce": ce, "tokens": denom}
