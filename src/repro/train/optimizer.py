"""In-house AdamW (no optax dependency) with global-norm clipping.

Optimizer state mirrors the parameter pytree (so it inherits the same
PartitionSpecs) plus a scalar step counter.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    mu: Any
    nu: Any
    step: jnp.ndarray


def init_opt_state(params) -> OptState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(
        mu=zeros,
        nu=jax.tree.map(jnp.zeros_like, zeros),
        step=jnp.zeros((), jnp.int32),
    )


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    s = step.astype(jnp.float32)
    warm = jnp.minimum(s / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip(
        (s - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    decay = cfg.min_lr_ratio + (1.0 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * decay


def global_norm(tree) -> jnp.ndarray:
    sq = sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), gn


def adamw_update(cfg: AdamWConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip and cfg.grad_clip > 0:
        grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gn = global_norm(grads)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1.0 - cfg.b1) * g
        v2 = cfg.b2 * v + (1.0 - cfg.b2) * jnp.square(g)
        mhat = m2 / b1c
        vhat = v2 / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m2, v2

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    metrics = {"grad_norm": gn, "lr": lr}
    return new_params, OptState(mu=new_mu, nu=new_nu, step=step), metrics
