from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state, lr_schedule
from .step import TrainConfig, lm_loss, make_train_step
