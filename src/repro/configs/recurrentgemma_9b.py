"""RecurrentGemma-9B (Griffin) — RG-LRU + local attention, 1 attn : 2 rnn.

[arXiv:2402.19427]. Pattern = (rnn, rnn, local) × 12 + remainder (rnn, rnn).
Bounded state ⇒ runs long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12_288,
    vocab=256_000,
    pattern=("rnn", "rnn", "local"),
    window=2048,
    rnn_width=4096,
    conv_width=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    supports_long_context=True,
)
