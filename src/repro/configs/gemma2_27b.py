"""Gemma2-27B — local+global alternating attention, logit softcaps.

[arXiv:2408.00118; hf]. Pattern = (local, global) × 23; window 4096;
attn softcap 50, final softcap 30; embeddings scaled by √d and tied.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab=256_000,
    pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    rope_theta=10_000.0,
    tie_embeddings=True,
    embed_scale=True,
    supports_long_context=False,  # global layers are full attention
)
