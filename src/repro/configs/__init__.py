"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published ModelConfig;
``SHAPES`` defines the four assigned input-shape cells;
``cells(arch_id)`` enumerates the runnable (arch × shape) cells with the
skip rules of DESIGN.md §7 applied.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ModelConfig

ARCHS = (
    "internvl2-2b",
    "qwen1_5-0_5b",
    "gemma2-27b",
    "qwen2-7b",
    "qwen2-0_5b",
    "whisper-small",
    "recurrentgemma-9b",
    "mixtral-8x22b",
    "qwen2-moe-a2_7b",
    "rwkv6-3b",
)

# canonical ids from the brief → module names
ALIASES = {
    "internvl2-2b": "internvl2-2b",
    "qwen1.5-0.5b": "qwen1_5-0_5b",
    "gemma2-27b": "gemma2-27b",
    "qwen2-7b": "qwen2-7b",
    "qwen2-0.5b": "qwen2-0_5b",
    "whisper-small": "whisper-small",
    "recurrentgemma-9b": "recurrentgemma-9b",
    "mixtral-8x22b": "mixtral-8x22b",
    "qwen2-moe-a2.7b": "qwen2-moe-a2_7b",
    "rwkv6-3b": "rwkv6-3b",
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def skip_reason(cfg: ModelConfig, shape: str) -> str | None:
    """Why a cell is skipped (None = runnable). DESIGN.md §7."""
    if shape == "long_500k" and not cfg.supports_long_context:
        return "full-attention KV at 500k is quadratic-prefill/unbounded-cache"
    if SHAPES[shape].step == "decode" and not cfg.has_decoder:
        return "encoder-only: no decode step"
    return None


def cells(arch: str):
    cfg = get_config(arch)
    return [
        (shape, skip_reason(cfg, shape)) for shape in SHAPES
    ]
