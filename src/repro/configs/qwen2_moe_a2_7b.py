"""Qwen1.5/2-MoE-A2.7B — 60 routed experts top-4 + 4 shared experts.

[hf:Qwen/Qwen1.5-MoE-A2.7B]. d_expert=1408; full attention (MHA kv=16).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=1408,
    vocab=151_936,
    pattern=("attn_moe",),
    n_experts=60,
    top_k=4,
    n_shared_experts=4,
    d_expert=1408,
    rope_theta=1_000_000.0,
    qkv_bias=True,
    supports_long_context=False,
)
