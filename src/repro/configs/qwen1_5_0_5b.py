"""Qwen1.5-0.5B — dense, QKV bias, MHA (kv=16). [hf:Qwen/Qwen1.5-0.5B]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab=151_936,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    supports_long_context=False,
)
