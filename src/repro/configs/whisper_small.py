"""Whisper-small — enc-dec, conv frontend stubbed. [arXiv:2212.04356].

Backbone only: ``input_specs`` provides precomputed frame embeddings
(B, 1500, 768) for the encoder; decoder uses learned positions.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab=51_865,
    pattern=("dec",),
    n_enc_layers=12,
    enc_seq=1500,
    pos_embedding="learned",
    mlp_act="gelu",
    tie_embeddings=True,
    supports_long_context=False,
)
