"""Qwen2-0.5B — dense GQA (kv=2), QKV bias, tied embeddings. [arXiv:2407.10671]."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    head_dim=64,
    d_ff=4864,
    vocab=151_936,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    qkv_bias=True,
    tie_embeddings=True,
    supports_long_context=False,
)
