"""Mixtral-8x22B — 8-expert top-2 MoE with sliding-window attention.

[arXiv:2401.04088; hf]. SWA window 4096 ⇒ bounded decode cache ⇒ long_500k.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab=32_768,
    pattern=("swa_moe",),
    window=4096,
    n_experts=8,
    top_k=2,
    d_expert=16_384,
    rope_theta=1_000_000.0,
    supports_long_context=True,
)
