"""RWKV-6 (Finch) 3B — attention-free, data-dependent decay.

[arXiv:2404.05892; hf]. 32 layers, d_model 2560, 40 heads of 64.
Constant-size state ⇒ long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,          # unused by rwkv kind (kept for bookkeeping)
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65_536,
    pattern=("rwkv",),
    rwkv_head_dim=64,
    rwkv_lora_r=64,
    tie_embeddings=False,
    supports_long_context=True,
)
