"""InternVL2-2B — InternViT frontend (stub) + InternLM2-1.8B LM backbone.

[arXiv:2404.16821; hf]. Backbone only per the brief; the vision frontend is
a stub supplying precomputed patch embeddings (``input_specs``).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=92_553,
    pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    supports_long_context=False,
)
