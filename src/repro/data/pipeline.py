"""Deterministic, shard-aware, restartable token pipeline.

Two sources:
* ``SyntheticLM`` — endless deterministic pseudo-corpus (hash-free,
  counter-based PRNG so any (step, shard) batch is recomputable — this is
  what makes data-state checkpointing trivial: the state is one integer);
* ``MemmapCorpus`` — flat uint16/uint32 token file (numpy memmap) cut into
  seq_len+1 windows, shuffled by a seeded permutation per epoch.

Both yield {"tokens", "labels", "mask"} with next-token alignment and
support ``state()``/``restore()`` for exact resume after preemption.
"""

from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    batch: int
    seq_len: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, st: dict):
        self.step = int(st["step"])

    def __iter__(self):
        return self

    def __next__(self):
        # Counter-based determinism: batch i is a pure function of (seed, i).
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step)
        toks = jax.random.randint(
            key, (self.batch, self.seq_len + 1), 0, self.vocab,
            dtype=jax.numpy.int32,
        )
        # inject learnable structure: make every 4th token a copy (so tiny
        # models can overfit in smoke tests / examples)
        toks = toks.at[:, 3::4].set(toks[:, 2::4])
        self.step += 1
        t = np.asarray(toks)
        return {
            "tokens": t[:, :-1],
            "labels": t[:, 1:],
            "mask": np.ones((self.batch, self.seq_len), np.float32),
        }


@dataclasses.dataclass
class MemmapCorpus:
    path: str
    batch: int
    seq_len: int
    dtype: str = "uint16"
    seed: int = 0
    shard_index: int = 0     # this host's shard
    num_shards: int = 1
    step: int = 0

    def __post_init__(self):
        self._data = np.memmap(self.path, dtype=self.dtype, mode="r")
        self._n_windows = (len(self._data) - 1) // self.seq_len
        if self._n_windows < self.batch:
            raise ValueError("corpus too small for one batch")

    def state(self) -> dict:
        return {"step": self.step}

    def restore(self, st: dict):
        self.step = int(st["step"])

    def _window(self, idx: int) -> np.ndarray:
        s = idx * self.seq_len
        return np.asarray(self._data[s : s + self.seq_len + 1], np.int32)

    def __iter__(self):
        return self

    def __next__(self):
        per_step = self.batch * self.num_shards
        epoch = (self.step * per_step) // self._n_windows
        rng = np.random.default_rng(self.seed + epoch)
        perm = rng.permutation(self._n_windows)
        base = (self.step * per_step) % self._n_windows
        idxs = [
            perm[(base + self.shard_index * self.batch + j) % self._n_windows]
            for j in range(self.batch)
        ]
        t = np.stack([self._window(i) for i in idxs])
        self.step += 1
        return {
            "tokens": t[:, :-1],
            "labels": t[:, 1:],
            "mask": np.ones((self.batch, self.seq_len), np.float32),
        }
