"""Production meshes.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state. The dry-run calls these after forcing 512
host-platform devices; real launches get the same topology from the TPU
runtime.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is not None:
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:
            pass
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int | None = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    if model is None:
        model = 1
        for cand in (2, 4):
            if n % cand == 0 and n >= cand * 2:
                model = cand
    data = n // model
    return _make_mesh((data, model), ("data", "model"))


def make_elastic_mesh(n_devices: int) -> Mesh:
    """Largest (data, model) mesh for an arbitrary live-device count —
    used by ft/elastic.py after shrink/grow events. Prefers model=16 when
    divisible, else the largest power-of-two divisor ≤ 16."""
    devices = jax.devices()[:n_devices]
    model = 1
    for cand in (16, 8, 4, 2):
        if n_devices % cand == 0:
            model = cand
            break
    data = n_devices // model
    import numpy as np

    dev_array = np.array(devices).reshape(data, model)
    if AxisType is not None:
        try:
            return Mesh(dev_array, ("data", "model"),
                        axis_types=(AxisType.Auto, AxisType.Auto))
        except TypeError:
            pass
    return Mesh(dev_array, ("data", "model"))
