import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Dry-run of the paper's solver itself at production scale (the cell
'most representative of the paper's technique' for §Perf).

Workload: distributed ridge-probe head fit —
    A  = backbone features, n = 2²¹ tokens × d = 8192 (row-sharded on data)
    B  = AᵀY for a c = 1024 vocab-slice readout (replicated)
    one adaptive *phase*: sketch (SJLT, m = 16384) → factorize H_S →
    10 PCG iterations — the whole phase as ONE jitted program.

Variants (selected with --variant, all must compile on both meshes):
  baseline   SJLT via segment-sum scatter, A row-sharded over data only —
             the paper's algorithm verbatim (model axis idle, as a faithful
             port of the single-node layout would leave it)
  2d         beyond-paper: A sharded (data × model) — every A-pass contracts
             a model-sharded d with one psum; 16× less per-device compute
  2d-bf16    2d + bf16 A-matvecs with f32 reductions (PCG is self-correcting;
             §Perf records the convergence check)
  flat       beyond-paper: n row-sharded over the FLATTENED mesh (256-way),
             d unsharded — PCG state (d×c) is small, so each iteration's
             only collective is the 33 MB AᵀAv partial-sum all-reduce
  flat-bf16  flat + bf16 matvecs
  gaussian   dense Gaussian sketch (bandwidth-maximal reference point)

Writes results/dryrun[_analysis]/<mesh>/solver__ridge[-variant].json in the
same record format as the arch cells.
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis.collectives import collective_bytes_from_hlo
from repro.analysis.hloflops import dot_flops_from_hlo
from repro.launch.mesh import make_production_mesh

N_TOKENS = 1 << 21
D_FEAT = 8192
N_CLASSES = 1024
M_SKETCH = 16384
NU = 1e-1
PCG_ITERS = 10


def _pcg_iters(A, b, P_solve, x0, iters, unroll, matvec_dtype=jnp.float32):
    """Matrix-RHS PCG on H = AᵀA + ν²I with preconditioner solve P_solve."""
    nu2 = jnp.asarray(NU * NU, jnp.float32)

    def hvp(v):
        Am = A.astype(matvec_dtype)
        av = (Am @ v.astype(matvec_dtype)).astype(jnp.float32)
        return (Am.T @ av.astype(matvec_dtype)).astype(jnp.float32) + nu2 * v

    r0 = b - hvp(x0)
    rt0 = P_solve(r0)
    dt0 = jnp.sum(r0 * rt0)

    def body(carry, _):
        x, r, rt, p, dt = carry
        Hp = hvp(p)
        alpha = dt / jnp.maximum(jnp.sum(p * Hp), 1e-30)
        x = x + alpha * p
        r = r - alpha * Hp
        rt = P_solve(r)
        dt_new = jnp.sum(r * rt)
        beta = dt_new / jnp.maximum(dt, 1e-30)
        p = rt + beta * p
        return (x, r, rt, p, dt_new), dt_new

    init = (x0, r0, rt0, rt0, dt0)
    (x, *_), trace = jax.lax.scan(body, init, None, length=iters,
                                  unroll=iters if unroll else 1)
    return x, trace


def make_step(variant: str, mesh, unroll: bool):
    data_axes = tuple(a for a in mesh.axis_names if a != "model")

    def sketch_baseline(A, rows, signs):
        # paper's SJLT as a global segment-sum (GSPMD partitions the scatter)
        return jax.ops.segment_sum(A * signs[:, None], rows,
                                   num_segments=M_SKETCH)

    def sketch_gaussian(A, key):
        S = jax.random.normal(key, (M_SKETCH, N_TOKENS), jnp.bfloat16)
        return (S @ A.astype(jnp.bfloat16)).astype(jnp.float32) / jnp.sqrt(
            jnp.asarray(M_SKETCH, jnp.float32)
        )

    def step(A, B, rows, signs, key):
        if variant == "gaussian":
            SA = sketch_gaussian(A, key)
        else:
            SA = sketch_baseline(A, rows, signs)
        nu2 = jnp.asarray(NU * NU, jnp.float32)
        H_S = SA.T @ SA + nu2 * jnp.eye(D_FEAT, dtype=jnp.float32)
        chol = jnp.linalg.cholesky(H_S)

        def P_solve(z):
            y = jax.scipy.linalg.solve_triangular(chol, z, lower=True)
            return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)

        x0 = jnp.zeros((D_FEAT, N_CLASSES), jnp.float32)
        mv_dtype = (jnp.bfloat16 if variant.endswith("bf16")
                    else jnp.float32)
        x, trace = _pcg_iters(A, B, P_solve, x0, PCG_ITERS, unroll,
                              matvec_dtype=mv_dtype)
        return x, trace[-1]

    return step


def run(variant: str, mesh_name: str, out_dir: Path, unroll: bool):
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    data_axes = tuple(a for a in mesh.axis_names if a != "model")
    rec = {"arch": f"solver-ridge-{variant}", "shape": "probe_2m_8k",
           "mesh": mesh_name, "params": D_FEAT * N_CLASSES,
           "active_params": D_FEAT * N_CLASSES}
    t0 = time.time()
    try:
        with mesh:
            step = make_step(variant, mesh, unroll)
            if variant.startswith("2d"):
                a_spec = P(data_axes, "model")
                v_spec = P(data_axes)
            elif variant.startswith("flat"):
                all_axes = data_axes + ("model",)
                a_spec = P(all_axes, None)
                v_spec = P(all_axes)
            else:
                a_spec = P(data_axes, None)
                v_spec = P(data_axes)
            a_sh = NamedSharding(mesh, a_spec)
            v_sh = NamedSharding(mesh, v_spec)
            rep = NamedSharding(mesh, P())
            sds = jax.ShapeDtypeStruct
            args = (
                sds((N_TOKENS, D_FEAT), jnp.float32),
                sds((D_FEAT, N_CLASSES), jnp.float32),
                sds((N_TOKENS,), jnp.int32),
                sds((N_TOKENS,), jnp.float32),
                sds((2,), jnp.uint32),
            )
            jitted = jax.jit(
                step,
                in_shardings=(a_sh, rep, v_sh, v_sh, rep),
                out_shardings=(rep, rep),
            )
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)
            hdf = dot_flops_from_hlo(hlo)
            rec.update(
                status="ok", step_kind="solver",
                lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
                memory={k: getattr(mem, k, None) for k in (
                    "argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")},
                flops=cost.get("flops"),
                hlo_dot_flops=hdf,
                bytes_accessed=cost.get("bytes accessed"),
                collectives=coll, n_devices=mesh.size,
            )
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out = out_dir / mesh_name / f"solver__ridge-{variant}.json"
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=2))
    msg = (f"compile={rec.get('compile_s')}s flops={rec.get('flops'):.3g} "
           f"coll={rec['collectives']['total_bytes']/2**30:.2f}GiB"
           if rec["status"] == "ok" else rec.get("error", "")[:200])
    print(f"[{rec['status']:5s}] {mesh_name}/solver-{variant}: {msg}",
          flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--variant", default="baseline",
                    choices=["baseline", "2d", "2d-bf16", "flat",
                             "flat-bf16", "gaussian"])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--unroll", action="store_true")
    args = ap.parse_args()
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    for m in meshes:
        run(args.variant, m, Path(args.out), args.unroll)


if __name__ == "__main__":
    main()
