import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

MUST be the process entry point (the XLA flag above is read at first jax
init). For each cell it records memory_analysis(), cost_analysis(), and the
collective-bytes sum parsed from the optimized HLO — incrementally to
results/dryrun/<mesh>/<arch>__<shape>.json so interrupted runs resume.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch A] [--shape S]
      [--mesh single|multi|both] [--out results/dryrun]
"""

import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import ALIASES, SHAPES, get_config, skip_reason
from repro.launch.mesh import make_production_mesh
from repro.analysis.collectives import collective_bytes_from_hlo
from repro.analysis.hloflops import dot_flops_from_hlo


def run_cell(arch: str, shape: str, mesh_name: str, out_dir: Path,
             force: bool = False, scan_unroll: bool = False,
             force_nmb=None, cfg_overrides=None, tag: str = "",
             fsdp: bool = True, ce_chunks: int = 0) -> dict:
    from repro.launch.specs import cell_specs

    out_file = out_dir / mesh_name / f"{arch}__{shape}{tag}.json"
    out_file.parent.mkdir(parents=True, exist_ok=True)
    if out_file.exists() and not force:
        rec = json.loads(out_file.read_text())
        if rec.get("status") in ("ok", "skipped"):
            print(f"[cached] {mesh_name}/{arch}/{shape}: {rec['status']}")
            return rec

    cfg = get_config(arch)
    reason = skip_reason(cfg, shape)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
    }
    if reason:
        rec.update(status="skipped", reason=reason)
        out_file.write_text(json.dumps(rec, indent=2))
        print(f"[skip]   {mesh_name}/{arch}/{shape}: {reason}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    t0 = time.time()
    try:
        with mesh:
            cell = cell_specs(arch, shape, mesh, scan_unroll=scan_unroll,
                              force_nmb=force_nmb,
                              cfg_overrides=cfg_overrides, fsdp=fsdp,
                              ce_chunks=ce_chunks)
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
            coll = collective_bytes_from_hlo(hlo)
            rec.update(
                status="ok",
                step_kind=cell.step_kind,
                lower_s=round(t_lower, 1),
                compile_s=round(t_compile, 1),
                memory={
                    k: getattr(mem, k, None)
                    for k in (
                        "argument_size_in_bytes",
                        "output_size_in_bytes",
                        "temp_size_in_bytes",
                        "generated_code_size_in_bytes",
                    )
                },
                flops=cost.get("flops"),
                hlo_dot_flops=dot_flops_from_hlo(hlo),
                bytes_accessed=cost.get("bytes accessed"),
                collectives=coll,
                n_devices=mesh.size,
            )
    except Exception as e:  # record failures — they are bugs to fix
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out_file.write_text(json.dumps(rec, indent=2))
    status = rec["status"]
    extra = (
        f"compile={rec.get('compile_s')}s flops={rec.get('flops'):.3g}"
        if status == "ok" else rec.get("error", "")[:200]
    )
    print(f"[{status:5s}] {mesh_name}/{arch}/{shape}: {extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer/microbatch scans so cost_analysis "
                         "counts every iteration (analysis sweep)")
    ap.add_argument("--override", default="",
                    help="comma k=v ModelConfig overrides (perf variants); "
                         "adds '-<k>' result-file tag")
    ap.add_argument("--ce-chunks", type=int, default=0,
                    help="blocked cross-entropy chunk count (perf variant)")
    ap.add_argument("--no-fsdp", action="store_true",
                    help="TP-only param sharding (perf variant; tags file)")
    ap.add_argument("--nmb1", action="store_true",
                    help="force num_microbatches=1 (same total FLOPs; "
                         "bounds analysis-compile time — see EXPERIMENTS.md)")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, (
        "dry-run must own jax init (512 host devices); do not import jax "
        "before this module"
    )
    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list(ALIASES)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": ["single"], "multi": ["multi"],
              "both": ["single", "multi"]}[args.mesh]
    n_ok = n_skip = n_err = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape in shapes:
                overrides = None
                tag = ""
                if args.override:
                    overrides = {}
                    for kv in args.override.split(","):
                        k, v = kv.split("=")
                        overrides[k] = (v == "1" or v == "true") if v in (
                            "0", "1", "true", "false") else (
                            int(v) if v.isdigit() else v)
                        tag += f"-{k.replace('_','')}"
                if args.no_fsdp:
                    tag += "-nofsdp"
                if args.ce_chunks:
                    tag += f"-ce{args.ce_chunks}"
                rec = run_cell(arch, shape, mesh_name, out_dir, args.force,
                               scan_unroll=args.unroll,
                               force_nmb=1 if args.nmb1 else None,
                               cfg_overrides=overrides, tag=tag,
                               fsdp=not args.no_fsdp,
                               ce_chunks=args.ce_chunks)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
    print(f"\ndone: {n_ok} ok, {n_skip} skipped, {n_err} errors")
    raise SystemExit(1 if n_err else 0)


if __name__ == "__main__":
    main()
