"""ShapeDtypeStruct stand-ins for every dry-run cell (no device allocation).

``cell_specs(arch, shape)`` returns everything the dry-run needs to lower a
cell: the step kind, abstract inputs, and their NamedShardings for a given
mesh. Parameters/optimizer/caches are derived with ``jax.eval_shape`` over
the real init functions, so the dry-run lowers the exact production program.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config
from repro.dist.sharding import (
    batch_axes,
    cache_specs,
    input_specs_for,
    param_specs,
)
from repro.models import init_cache, init_params
from repro.models.config import ModelConfig
from repro.serve.step import decode_step, prefill_step
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.step import TrainConfig, make_train_step


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def microbatches_for(cfg: ModelConfig, global_batch: int) -> int:
    """Keep per-microbatch logits + activations bounded: target a global
    microbatch of 32 sequences for wide models, 64 otherwise."""
    target = 32 if cfg.d_model >= 3584 or cfg.n_experts else 64
    nmb = max(1, global_batch // target)
    while global_batch % nmb:
        nmb -= 1
    return nmb


@dataclasses.dataclass
class CellSpec:
    arch: str
    shape: str
    step_kind: str                  # train | prefill | decode
    fn: Callable                    # jit-able (positional pytree args)
    args: tuple                     # ShapeDtypeStructs
    in_shardings: tuple
    out_shardings: Any
    donate: tuple = ()


def params_dtype_struct(cfg: ModelConfig, max_seq: int, dtype=None):
    tree = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
    )
    if dtype is not None:
        tree = jax.tree.map(lambda s: _sds(s.shape, dtype), tree)
    return tree


def cell_specs(arch: str, shape_name: str, mesh: Mesh,
               *, scan_unroll: bool = False,
               force_nmb: int | None = None,
               cfg_overrides: dict | None = None,
               fsdp: bool = True, ce_chunks: int = 0) -> CellSpec:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shp = SHAPES[shape_name]
    B, S = shp.global_batch, shp.seq_len
    dp = batch_axes(mesh)
    ns = lambda spec: NamedSharding(mesh, spec)
    tok_sh = ns(input_specs_for(mesh, B))

    if shp.step == "train":
        max_seq = S if cfg.pos_embedding == "learned" else 4096
        p_shapes = params_dtype_struct(cfg, max_seq)
        opt_shapes = jax.eval_shape(init_opt_state, p_shapes)
        p_spec = param_specs(cfg, p_shapes, mesh, fsdp=fsdp)
        p_sh = jax.tree.map(ns, p_spec)
        opt_sh = type(opt_shapes)(
            mu=jax.tree.map(ns, p_spec),
            nu=jax.tree.map(ns, p_spec),
            step=ns(P()),
        )
        batch = {
            "tokens": _sds((B, S), jnp.int32),
            "labels": _sds((B, S), jnp.int32),
            "mask": _sds((B, S), jnp.float32),
        }
        batch_sh = {"tokens": tok_sh, "labels": tok_sh, "mask": tok_sh}
        if cfg.n_enc_layers:
            batch["enc_feats"] = _sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
            batch_sh["enc_feats"] = ns(P(*input_specs_for(mesh, B), None))
        tcfg = TrainConfig(
            opt=AdamWConfig(),
            num_microbatches=force_nmb or microbatches_for(cfg, B),
            scan_unroll=scan_unroll,
            ce_chunks=ce_chunks,
        )
        fn = make_train_step(cfg, tcfg)
        metrics_sh = {k: ns(P()) for k in
                      ("loss", "ce", "grad_norm", "lr")}
        return CellSpec(
            arch=arch, shape=shape_name, step_kind="train", fn=fn,
            args=(p_shapes, opt_shapes, batch),
            in_shardings=(p_sh, opt_sh, batch_sh),
            out_shardings=(p_sh, opt_sh, metrics_sh),
            donate=(0, 1),
        )

    # ---- inference cells: params in bf16, TP-sharded ----
    max_seq = S
    p_shapes = params_dtype_struct(cfg, max_seq, dtype=jnp.bfloat16)
    p_spec = param_specs(cfg, p_shapes, mesh, fsdp=False)
    p_sh = jax.tree.map(ns, p_spec)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, B, max_seq, dtype=jnp.bfloat16)
    )
    c_spec = cache_specs(cfg, cache_shapes, mesh)
    c_sh = jax.tree.map(ns, c_spec)
    logits_sh = ns(input_specs_for(mesh, B))

    if shp.step == "prefill":
        def fn(params, tokens, cache, enc_feats=None):
            return prefill_step(
                params, cfg, tokens, cache, enc_feats=enc_feats,
                scan_unroll=scan_unroll,
            )

        args = [p_shapes, _sds((B, S), jnp.int32), cache_shapes]
        in_sh = [p_sh, tok_sh, c_sh]
        if cfg.n_enc_layers:
            args.append(_sds((B, cfg.enc_seq, cfg.d_model), jnp.bfloat16))
            in_sh.append(ns(P(*input_specs_for(mesh, B), None)))
        return CellSpec(
            arch=arch, shape=shape_name, step_kind="prefill",
            fn=fn, args=tuple(args), in_shardings=tuple(in_sh),
            out_shardings=(logits_sh, c_sh),
            donate=(2,),
        )

    # decode: one new token against a seq_len cache
    def dfn(params, token, cache, cache_pos):
        return decode_step(params, cfg, token, cache, cache_pos,
                           scan_unroll=scan_unroll)

    args = (
        p_shapes,
        _sds((B, 1), jnp.int32),
        cache_shapes,
        _sds((), jnp.int32),
    )
    in_sh = (p_sh, tok_sh, c_sh, ns(P()))
    return CellSpec(
        arch=arch, shape=shape_name, step_kind="decode",
        fn=dfn, args=args, in_shardings=in_sh,
        out_shardings=(logits_sh, c_sh),
        donate=(2,),
    )
