"""End-to-end training launcher (CPU-scale on this container; same code
path the pod launch uses, minus the device count).

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --steps 200 --reduced --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Wires together: config → params → mesh + shardings → data pipeline →
train_step → checkpoint manager + straggler watchdog + preemption handler,
with auto-resume from the latest committed checkpoint.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.dist.sharding import param_specs
from repro.ft import CheckpointManager, PreemptionHandler, StragglerWatchdog
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.train import AdamWConfig, TrainConfig, init_opt_state
from repro.train.step import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--compute-dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh()
    print(f"arch={cfg.name} params={cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        num_microbatches=args.microbatches,
        compute_dtype=jnp.dtype(args.compute_dtype),
        remat=True,
    )
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=args.seq)
    opt_state = init_opt_state(params)
    p_sh = jax.tree.map(
        lambda s: NamedSharding(mesh, s), param_specs(cfg, params, mesh)
    )
    params = jax.device_put(params, p_sh)
    data = SyntheticLM(vocab=cfg.vocab, batch=args.batch, seq_len=args.seq)

    step_fn = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

    ckpt = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if ckpt and ckpt.latest_step() is not None:
        (params, opt_state), extra = ckpt.restore((params, opt_state))
        data.restore(extra["data"])
        start = extra["step"]
        print(f"resumed from step {start}")

    watchdog = StragglerWatchdog()
    with mesh, PreemptionHandler() as preempt:
        t0 = time.perf_counter()
        for step in range(start, args.steps):
            batch = jax.tree.map(jnp.asarray, next(data))
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            dt = time.perf_counter() - t0
            t0 = time.perf_counter()
            watchdog.record(dt)
            if (step + 1) % args.log_every == 0:
                m = jax.tree.map(float, metrics)
                print(f"step {step+1:5d} loss={m['loss']:.4f} "
                      f"ce={m['ce']:.4f} gnorm={m['grad_norm']:.3f} "
                      f"lr={m['lr']:.2e} dt={dt*1e3:.0f}ms")
            if ckpt and (step + 1) % args.save_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          extra={"step": step + 1, "data": data.state()},
                          blocking=False)
            if preempt.should_stop:
                print("preemption requested — checkpointing and exiting")
                if ckpt:
                    ckpt.wait()
                    ckpt.save(step + 1, (params, opt_state),
                              extra={"step": step + 1, "data": data.state()})
                return
        if ckpt:
            ckpt.wait()
            ckpt.save(args.steps, (params, opt_state),
                      extra={"step": args.steps, "data": data.state()})
    if watchdog.flagged:
        print("straggler hosts flagged:", watchdog.flagged)
    print("training complete")


if __name__ == "__main__":
    main()
