"""Batched serving launcher: load (or init) a model, prefill a batch of
prompts, stream greedy continuations. CPU-scale here; the pod launch uses
the same decode_step under the production mesh (see launch/dryrun.py
decode cells for the compiled configuration).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced

``--ridge`` serves the other production workload instead: a stream of
heterogeneous ridge-solve requests, bucketed into shape classes and solved
in fixed-shape batches by the multi-problem adaptive engine
(serve/solver_service.py, DESIGN.md §6):

    PYTHONPATH=src python -m repro.launch.serve --ridge --requests 64 \
        --ridge-batch 16 [--sketch srht] [--dtype bf16] [--mesh 8] [--glm 16]

(``--ridge-batch`` sizes the packed solver batches; ``--mesh K`` runs the
sharded engine over a K-device data mesh — see DESIGN.md §5; ``--glm N``
adds N logistic requests served by the adaptive sketched-Newton driver
with Newton-level certificates — DESIGN.md §8; ``--dtype bf16``/``int8``
runs the one-touch sketch pass at reduced stream precision with fp32
certificates — DESIGN.md §10; ``--deadline-s T`` bounds the flush —
expired requests return DEADLINE_EXCEEDED with their best finite iterate
— DESIGN.md §11; ``--path N`` adds N regularization-path requests, each a
``--path-points``-long λ grid solved off ONE one-touch sketch pass with
warm-started per-λ solves, plus a repeated-A round served entirely from
the fingerprint ladder cache — DESIGN.md §13.)

``--preempt-after N`` drives the preemption chaos cycle instead (DESIGN.md
§11): launch ``examples/solve_service.py`` as a checkpointing subprocess,
SIGTERM it N seconds into the flush, assert it exits 75 after committing
its solver state, restart it with ``--resume``, and assert every request
terminates finite with an honest status:

    PYTHONPATH=src python -m repro.launch.serve --preempt-after 3
"""

from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.ft import CheckpointManager
from repro.models import init_params
from repro.serve.step import greedy_generate


def serve_ridge(args):
    """Ridge-solve serving demo: random-shape requests through the
    shape-class bucketing + batched adaptive engine. ``--mesh K`` places
    each packed batch's A row-sharded over a K-device data mesh (run under
    XLA_FLAGS=--xla_force_host_platform_device_count=K to demo on CPU)."""
    import numpy as np

    from repro.serve.solver_service import SolverService

    mesh = None
    if args.mesh:
        if args.mesh > jax.device_count():
            raise SystemExit(
                f"--mesh {args.mesh} needs {args.mesh} devices but only "
                f"{jax.device_count()} exist; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count={args.mesh}")
        mesh = jax.make_mesh((args.mesh,), ("data",))
    from repro.serve.solver_service import GLMSolution, PathSolution

    svc = SolverService(batch_size=args.ridge_batch, method="pcg",
                        sketch=args.sketch, compute_dtype=args.dtype,
                        mesh=mesh, strict=not args.faulty,
                        ladder_cache=bool(args.path))
    rng = np.random.default_rng(0)
    truth = {}
    for i in range(args.requests):
        n = int(rng.integers(64, 1800))
        d = int(rng.integers(8, 120))
        A = jax.random.normal(jax.random.PRNGKey(2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(2 * i + 1), (n,))
        rid = svc.submit(A, y, nu=float(rng.uniform(0.05, 0.5)))
        truth[rid] = (A, y)
    for i in range(args.faulty):
        # quarantine-path demo: NaN-poisoned requests ride the same flush
        # and come back REJECTED without touching their packed neighbors
        A = jnp.full((128, 16), jnp.nan)
        svc.submit(A, jnp.zeros(128), nu=0.1)
    from repro.core.objectives import synthetic_logistic_problem

    for i in range(args.glm):
        n = int(rng.integers(64, 1800))
        d = int(rng.integers(8, 120))
        A, y = synthetic_logistic_problem(jax.random.PRNGKey(10_000 + i),
                                          n, d)
        svc.submit_glm(A, y, nu=float(rng.uniform(0.1, 0.5)),
                       family="logistic")
    path_truth = {}
    for i in range(args.path):
        # regularization-path traffic (DESIGN.md §13): each request is a λ
        # GRID solved off ONE one-touch sketch pass, strong→weak so warm
        # starts move downhill
        n = int(rng.integers(64, 1800))
        d = int(rng.integers(8, 120))
        A = jax.random.normal(
            jax.random.PRNGKey(20_000 + 2 * i), (n, d)) / np.sqrt(n)
        y = jax.random.normal(jax.random.PRNGKey(20_001 + 2 * i), (n,))
        nus = np.geomspace(1.0, 1e-2, args.path_points)
        rid = svc.submit_path(A, y, nus)
        path_truth[rid] = (A, y, nus)
    t0 = time.perf_counter()
    sols = svc.flush(deadline_s=args.deadline_s)
    dt = time.perf_counter() - t0
    if not sols:
        print("ridge service: no requests")
        return
    ridge_sols = [s for s in sols.values()
                  if not isinstance(s, (GLMSolution, PathSolution))]
    glm_sols = [s for s in sols.values() if isinstance(s, GLMSolution)]
    path_sols = [s for s in sols.values() if isinstance(s, PathSolution)]
    n_req = args.requests + args.glm + args.path
    mesh_note = f", {args.mesh}-way data mesh" if mesh is not None else ""
    print(f"solver service: {n_req} requests in {dt:.2f}s "
          f"({n_req / dt:.1f} req/s incl. compile) — "
          f"{svc.stats['batches']} batches of {svc.batch_size}, "
          f"{svc.stats['padded_slots']} padded slots "
          f"({100 * svc.slot_utilization():.0f}% slot utilization"
          f"{mesh_note})")
    # only converged solutions carry a trustworthy δ̃ certificate; rejected /
    # fallen-back / expired ones report NaN there by design
    ridge_ok = [s for s in ridge_sols if s.converged]
    if ridge_ok:
        m_finals = [s.m_final for s in ridge_ok]
        fams = sorted({s.sketch for s in ridge_ok})
        dts = sorted({s.compute_dtype for s in ridge_ok})
        print(f"ridge certificates ({'/'.join(fams)}, "
              f"dtype {'/'.join(dts)}): "
              f"m_final min/median/max = "
              f"{min(m_finals)}/{sorted(m_finals)[len(m_finals) // 2]}/"
              f"{max(m_finals)}, "
              f"max residual δ̃ = {max(s.delta_tilde for s in ridge_ok):.2e}")
    # failure-model report (DESIGN.md §9): every request has a verdict
    counts: dict[str, int] = {}
    for s in sols.values():
        counts[s.status] = counts.get(s.status, 0) + 1
    verdicts = ", ".join(f"{k}={v}" for k, v in sorted(counts.items()))
    print(f"statuses: {verdicts}; retries={svc.stats['retries']}, "
          f"fallbacks={svc.stats['fallbacks']}, "
          f"rejected={svc.stats['rejected']}, "
          f"deadline_exceeded={svc.stats['deadline_exceeded']}")
    if glm_sols:
        outer = [s.newton_iters for s in glm_sols]
        print(f"glm certificates (logistic): "
              f"{sum(s.converged for s in glm_sols)}/{len(glm_sols)} "
              f"converged, outer iters min/max = {min(outer)}/{max(outer)}, "
              f"max decrement λ̃²/2 = "
              f"{max(s.decrement for s in glm_sols):.2e}, "
              f"m trajectory (req {glm_sols[0].req_id}): "
              f"{glm_sols[0].m_trajectory}")
    if path_sols:
        pts = [p for s in path_sols for p in s.points]
        passes = sum(s.sketch_passes for s in path_sols)
        s0 = path_sols[0]
        print(f"path certificates: {sum(s.converged for s in path_sols)}/"
              f"{len(path_sols)} grids converged "
              f"({args.path_points} λ points each), "
              f"{passes} one-touch passes total, "
              f"max δ̃ = {max(p.delta_tilde for p in pts):.2e}, "
              f"warm m trajectory (req {s0.req_id}): "
              f"{tuple(p.m_final for p in s0.points)}")
        # repeated-A round: the λ-free ladder is keyed by content
        # fingerprint, so the re-submitted grid never touches A again
        rid0 = min(path_truth)
        A, y, nus = path_truth[rid0]
        rid_warm = svc.submit_path(A, y, nus)
        warm = svc.flush()[rid_warm]
        print(f"repeat-A path round: cache_hit={warm.cache_hit}, "
              f"sketch_passes={warm.sketch_passes} "
              f"(ladder served from the fingerprint cache; "
              f"{svc.stats['sketch_passes_saved']} passes saved)")


def serve_preempt(args):
    """The kill → restart serving story, end to end (DESIGN.md §11):
    run the checkpointing ridge demo as a subprocess, SIGTERM it
    ``--preempt-after`` seconds in, restart with ``--resume``, and verify
    every request still terminates finite with a truthful status."""
    import os
    import shutil
    import signal
    import subprocess
    import sys
    import tempfile

    root = Path(__file__).resolve().parents[3]
    ck = tempfile.mkdtemp(prefix="preempt_ck_")
    env = {**os.environ,
           "PYTHONPATH": os.pathsep.join(
               [str(root / "src")]
               + ([os.environ["PYTHONPATH"]]
                  if os.environ.get("PYTHONPATH") else []))}
    # tol=0 + bounded iters + no fallback keeps the flush long enough for
    # the signal to land mid-solve, while still terminating on restart
    cmd = [sys.executable, "-u", str(root / "examples" / "solve_service.py"),
           "--requests", "6", "--tol", "0", "--max-iters", "1200",
           "--max-retries", "0", "--no-fallback", "--segment-trips", "16",
           "--checkpoint-dir", ck]
    try:
        print(f"preemption cycle: checkpoints in {ck}")
        p = subprocess.Popen(cmd, env=env, stdout=subprocess.PIPE,
                             stderr=subprocess.STDOUT, text=True)
        time.sleep(args.preempt_after)
        p.send_signal(signal.SIGTERM)
        out, _ = p.communicate(timeout=600)
        print(out, end="")
        if p.returncode == 0:
            print("note: flush finished before the SIGTERM landed; "
                  "restart will resume-from-complete")
        elif p.returncode != 75:
            raise SystemExit(
                f"preempted service exited {p.returncode}, expected 75")
        r = subprocess.run(cmd + ["--resume"], env=env,
                           capture_output=True, text=True, timeout=600)
        print(r.stdout, end="")
        if r.returncode != 0:
            raise SystemExit(
                f"resumed service exited {r.returncode}:\n"
                f"{r.stderr[-2000:]}")
        if "ALL_FINITE=1" not in r.stdout:
            raise SystemExit("resumed service returned non-finite answers")
        print("preemption cycle OK: SIGTERM → exit 75 → --resume → "
              "all requests finite with honest statuses")
    finally:
        shutil.rmtree(ck, ignore_errors=True)


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4,
                    help="LM-decode batch size (NOT the ridge batch)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="",
                    help="restore params from a training checkpoint")
    ap.add_argument("--ridge", action="store_true",
                    help="serve ridge-solve requests instead of LM decode")
    ap.add_argument("--requests", type=int, default=48,
                    help="number of synthetic ridge requests (--ridge)")
    ap.add_argument("--glm", type=int, default=0,
                    help="additionally serve this many synthetic logistic "
                         "requests through the sketched-Newton path "
                         "(--ridge; certificates include outer iterations, "
                         "Newton decrement and the m trajectory)")
    ap.add_argument("--path", type=int, default=0,
                    help="additionally serve this many regularization-path "
                         "requests (--ridge): each is a λ grid solved off "
                         "ONE one-touch sketch pass with warm-started "
                         "per-λ solves; also runs a repeated-A round "
                         "served from the fingerprint ladder cache "
                         "(DESIGN.md §13)")
    ap.add_argument("--path-points", type=int, default=8,
                    help="λ points per path request (--path), geomspace "
                         "1.0 → 1e-2 strong→weak")
    ap.add_argument("--ridge-batch", type=int, default=16,
                    help="packed batch size per shape class (--ridge); "
                         "its own flag so the LM --batch default of 4 "
                         "cannot silently leave 3/4 of the slots padded")
    ap.add_argument("--faulty", type=int, default=0,
                    help="additionally submit this many NaN-poisoned ridge "
                         "requests (--ridge); runs the service with "
                         "strict=False so they exercise the quarantine → "
                         "REJECTED path instead of raising at submit")
    ap.add_argument("--mesh", type=int, default=0,
                    help="row-shard each packed batch's A over this many "
                         "data-mesh devices (--ridge); 0 = single device")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="wall-clock budget for the ridge flush (--ridge); "
                         "expired requests return DEADLINE_EXCEEDED with "
                         "their best finite iterate (DESIGN.md §11)")
    ap.add_argument("--preempt-after", type=float, default=0.0,
                    help="run the preemption chaos cycle instead: SIGTERM "
                         "the checkpointing ridge demo this many seconds "
                         "into its flush, then restart it with --resume "
                         "and verify finite, honest results")
    from repro.core.level_grams import COMPUTE_DTYPES, PADDED_SKETCHES

    ap.add_argument("--sketch", default="gaussian",
                    choices=PADDED_SKETCHES,
                    help="sketch family for the ridge service (--ridge)")
    ap.add_argument("--dtype", default="fp32", choices=COMPUTE_DTYPES,
                    help="sketch-pass compute dtype for the ridge service "
                         "(--ridge): bf16 streams/contracts sketch operands "
                         "in bfloat16 with fp32 accumulation, int8 "
                         "additionally quantizes A per row; certificates "
                         "stay fp32 and record the mode (DESIGN.md §10)")
    return ap


def main(argv=None):
    args = build_parser().parse_args(argv)

    if args.preempt_after:
        return serve_preempt(args)
    if args.ridge:
        return serve_ridge(args)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.new_tokens + 1
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        (params, _), _ = mgr.restore((params, None))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.n_enc_layers:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model)
        )
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompts, args.new_tokens,
                          max_seq=max_seq, enc_feats=enc)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch}×{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print("ids:", out[0].tolist())


if __name__ == "__main__":
    main()
