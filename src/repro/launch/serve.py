"""Batched serving launcher: load (or init) a model, prefill a batch of
prompts, stream greedy continuations. CPU-scale here; the pod launch uses
the same decode_step under the production mesh (see launch/dryrun.py
decode cells for the compiled configuration).

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-3b --reduced
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.ft import CheckpointManager
from repro.models import init_params
from repro.serve.step import greedy_generate


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--ckpt-dir", default="",
                    help="restore params from a training checkpoint")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    max_seq = args.prompt_len + args.new_tokens + 1
    params = init_params(jax.random.PRNGKey(0), cfg, max_seq=max_seq)
    if args.ckpt_dir:
        mgr = CheckpointManager(args.ckpt_dir)
        (params, _), _ = mgr.restore((params, None))

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    enc = None
    if cfg.n_enc_layers:
        enc = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.enc_seq, cfg.d_model)
        )
    t0 = time.perf_counter()
    out = greedy_generate(params, cfg, prompts, args.new_tokens,
                          max_seq=max_seq, enc_feats=enc)
    dt = time.perf_counter() - t0
    print(f"{cfg.name}: {args.batch}×{args.new_tokens} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s)")
    print("ids:", out[0].tolist())


if __name__ == "__main__":
    main()
