"""Beyond-paper: fully-jitted adaptive solver with a *padded* sketch.

The paper's Algorithm 4.1 changes the sketch shape at runtime (m doubles),
which forces either recompilation per size or host orchestration
(``core.adaptive``). In serving/TPU environments with fixed-shape
executables, we instead:

* allocate the sketch at a maximum size m_max once;
* keep an *active-row count* m_t as a traced integer; rows ≥ m_t are masked
  to zero and the live rows are rescaled so the masked sketch has exactly
  the law of an m_t-row sketch;
* run the whole adaptive loop as one ``lax.while_loop`` — the improvement
  test, doubling (m_t ← 2·m_t, i.e. unmask more rows) and refactorization
  are all inside the compiled graph.

Multi-problem engine (DESIGN.md §6): the loop is *batch-polymorphic*. A
batched ``Quadratic`` (B problems, per-problem A or shared A) is solved by
ONE while_loop in which m_t, the restart clock t_rel, δ̃_I and the
convergence flag are all per-problem (B,) vectors — each problem follows
its own doubling schedule (driven by its own effective dimension, per
arXiv:2006.05874) inside a single executable. Refactorization is batched:
whenever any problem rejects, the masked factorization is recomputed for
the whole batch at the updated per-problem sizes (unchanged problems
reproduce their factor bit-for-bit, so this is a no-op for them).

Sketch families are pluggable ``LevelGramProvider``s (``core.level_grams``,
DESIGN.md §6): ``gaussian`` (streamed — rows generated on the fly inside
the fused sketch→Gram kernel, masking = prefix of the i.i.d. row stream),
``gaussian_dense`` (the materialized-S memory baseline, same entries),
``sjlt`` (fixed (u, sign) stream; the level-m target ⌊u_i·m⌋ is uniform for
every m and pow2 levels fold pairwise from ONE dispatch), and ``srht``
(one sign flip + one FWHT pass; level-m = the first m rows of a fixed
uniform row-sample stream).

Methods: ``ihs`` (Thm 3.2 thresholds: φ(ρ)=ρ, α=1), ``pcg``
(Alg 4.2 thresholds: φ(ρ)=(1−√(1−ρ))/(1+√(1−ρ)), α=4) and ``polyak``
(heavy-ball, Appendix A — same thresholds as PCG, with the momentum
anchor x_prev reset on every doubling); the method restarts at the
current iterate on every doubling, as in Algorithm 4.1.

Weighted problems (``q.row_weights``) and warm-started ladders
(``init_level``) serve the GLM Newton driver (``core.newton``,
DESIGN.md §8): the sketch pass embeds W^{1/2}A in the same one touch of A
and the doubling ladder resumes where the previous Newton step left it.

Cost model: m_t only ever visits the doubling ladder {1, 2, 4, …, m_max},
so the sketched Gram (SA)ᵀ(SA) is PRECOMPUTED at every ladder level before
the loop starts, by the family's provider, touching A exactly ONCE —
matching the paper's O(sketch) + Σ O(factorize) accounting. The sketch
pass *streams* A: the Gaussian family fuses row generation with the A
contraction (``kernels.gaussian_gram`` on TPU, a chunked ``lax.scan``
elsewhere) so S never exists in HBM; the SJLT routes one dispatch through
the Pallas MXU kernel and folds the ladder down; the SRHT pays one FWHT.
Precompute live memory is O(B·m_max·d) row streams + O(B·d²·L) level Grams
— never O(B·m_max·n). The in-loop refactorization is only a (B,) gather of
precomputed level inverses, and H_S is factorized in the primal (d×d) form
for every m_t (ν²Λ ≻ 0 keeps it SPD below d). In exchange for the padded
d×d factor there is exactly ONE executable and no host round-trips — the
right trade on real TPU pods where launch latency and recompiles dominate
at small m.

Sharding: ``mesh=`` row-shards A over the mesh's data axes and swaps ONLY
the precompute for the sharded one-touch pass (each shard runs its
family's ladder pass on its rows with independent per-shard randomness;
ONE psum of the (L, B, d, d) level Grams — ``distributed.shard_level_grams``,
DESIGN.md §5); the while_loop and all of the above are unchanged.

Segmentation (DESIGN.md §11): the solve decomposes into four reusable,
individually-jitted pieces — ``prepare_padded_solve`` (one-touch ladder
pass + factorizations + guard tables + optional Gram precompute, returning
a ``PaddedPrecompute`` and the initial ``PaddedState``),
``padded_solve_segment`` (the SAME while_loop body run up to a *traced*
trip limit — one compiled executable re-dispatched per segment),
``finalize_padded_solve`` (the status lattice + certificates), and
``reprecondition_padded`` (rebuild the ladder from replacement level Grams
mid-solve and re-anchor every unfinished problem at its current iterate —
elastic shard recovery). ``padded_adaptive_solve_batched`` is these pieces
composed in one jit with the trip limit pinned at the trip cap, so the
monolithic path is bit-identical to running the segments back-to-back.
The full ``PaddedState`` (iterates, best-iterate, per-problem level,
residual/δ̃ state, counters) is an exported NamedTuple of plain arrays —
exactly what a checkpoint of a preempted solve persists
(``core.robust.segmented_padded_solve_batched``).
"""

from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.precision import canonical_compute_dtype

from .level_grams import get_provider
from .precond import shifted_ladder_inverses
from .quadratic import Quadratic, weighted_gram
from .solvers import c_alpha_rho, rho_to_rate
from .status import SolveStatus

PADDED_METHODS = ("ihs", "pcg", "polyak")


class PaddedState(NamedTuple):
    x: jnp.ndarray            # (B, d) iterates
    x_prev: jnp.ndarray       # (B, d) previous iterate (Polyak momentum)
    r: jnp.ndarray            # (B, d) PCG residual (zeros for IHS)
    rt: jnp.ndarray           # (B, d) PCG preconditioned residual
    p: jnp.ndarray            # (B, d) PCG search direction
    grad: jnp.ndarray         # (B, d) gradient at x (IHS)
    level: jnp.ndarray        # (B,)  index into the doubling ladder (int32)
    t_rel: jnp.ndarray        # (B,)  iterations since last restart
    dtilde_I: jnp.ndarray     # (B,)  δ̃ at last restart
    dtilde: jnp.ndarray       # (B,)  current δ̃
    dtilde0: jnp.ndarray      # (B,)  δ̃ at x₀ under the current sketch
    x_best: jnp.ndarray       # (B, d) best iterate under the current metric
    dt_best: jnp.ndarray      # (B,)  its δ̃ (the returned certificate)
    pinv: jnp.ndarray         # (B, d, d) gathered H_S⁻¹ at the current level
    iters: jnp.ndarray        # (B,)  accepted iterations
    doublings: jnp.ndarray    # (B,)
    done: jnp.ndarray         # (B,)  bool
    converged: jnp.ndarray    # (B,)  bool: δ̃ cleared tol (honest, not "done")
    nan_hit: jnp.ndarray      # (B,)  bool: a non-finite proposal was seen
    trips: jnp.ndarray        # scalar loop-trip counter


class PaddedPrecompute(NamedTuple):
    """Everything the while_loop body reads that is NOT per-iteration state:
    the factorized ladder, the guard tables and the (optional) precomputed
    true Gram. Produced once per solve by ``prepare_padded_solve`` (or
    inline by ``padded_adaptive_solve_batched``); rebuilt mid-solve only by
    ``reprecondition_padded`` (elastic shard recovery, DESIGN.md §11).
    A pytree of plain arrays — deterministic given (q, keys), so a resumed
    process recomputes it instead of checkpointing O(L·B·d²) bytes."""
    pinvs: jnp.ndarray           # (L, B, d, d) remapped per-level H_S⁻¹
    remap: jnp.ndarray           # (L, B) valid-level redirect (identity
                                 # when guards are off); −1 ⇒ none valid
    any_valid: jnp.ndarray       # (B,)  problem has ≥1 usable ladder level
    gram_poisoned: jnp.ndarray   # (B,)  some level Gram was non-finite
    invalid_levels: jnp.ndarray  # (B,)  count of skipped ladder levels
    G_full: jnp.ndarray | None   # precomputed AᵀA / AᵀWA ((d, d) shared or
                                 # (B, d, d)); None ⇒ matrix-free hvp


def _apply_pinv(pinv, z):
    """H_S⁻¹ z as one fused batched matvec — the in-loop hot path."""
    return jnp.einsum("bde,be->bd", pinv, z)


def _pdot(a, b):
    return jnp.sum(a * b, axis=-1)


def _is_single_key(keys: jax.Array) -> bool:
    """One PRNG key vs a batch of keys, for both key flavors: typed keys
    (jax.random.key — a key is a rank-0 array) and legacy uint32 keys
    (jax.random.PRNGKey — a key is a rank-1 (2,) array)."""
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        return keys.ndim == 0
    return keys.ndim == 1


def doubling_ladder(m_max: int) -> tuple[int, ...]:
    """The sizes m_t can visit: 1, 2, 4, …, capped at m_max."""
    ms, m = [], 1
    while m < m_max:
        ms.append(m)
        m *= 2
    ms.append(m_max)
    return tuple(ms)


def padded_trip_cap(m_max: int, max_iters: int) -> int:
    """Loop-trip safety cap: rejects per problem are bounded by the ladder
    length, so this is a net on top of the per-problem iteration cap."""
    return max_iters + len(doubling_ladder(m_max)) + 3


def _field_dtype(q: Quadratic):
    return q.A.dtype if q.A.dtype != jnp.int8 else jnp.float32


def _precompute_pinvs(grams: jnp.ndarray, q: Quadratic) -> jnp.ndarray:
    """(L, B, d, d) explicit H_S⁻¹ at EVERY ladder level, as one flattened
    batched Cholesky + triangular inverse before the loop starts.

    With the inverses precomputed, the in-loop "refactorization" on a
    doubling is a pure (B,) gather and the per-iteration preconditioner
    application is one fused batched matvec — no LAPACK dispatch anywhere
    inside the while_loop. The extra work vs factorizing on demand is at
    most the ladder length × a d×d Cholesky, a rounding error next to the
    sketch pass; the forward error of an explicit inverse is the same
    O(ε·κ) as triangular solves, which a *preconditioner* tolerates.

    The Grams themselves are λ-FREE — the ν²Λ shift enters only inside
    ``precond.shifted_ladder_inverses`` — which is what lets a
    regularization path reuse one ladder across every λ (DESIGN.md §13)."""
    return shifted_ladder_inverses(grams, q.nu, q.lam_diag)


def _gather_pinv(pinvs: jnp.ndarray, level: jnp.ndarray) -> jnp.ndarray:
    """Select each problem's preconditioner at its current ladder level."""
    return pinvs[level, jnp.arange(level.shape[0])]


def _valid_level_remap(level_ok: jnp.ndarray):
    """Per-(level, problem) redirect around invalid ladder levels.

    ``level_ok`` (L, B) marks levels whose sketched Gram AND its factorized
    inverse are entirely finite. A level can be individually invalid (a
    rank-deficient low-m sketched Gram under ν ≈ 0 Choleskys to NaN) without
    the problem being hopeless — the doubling controller should *skip* it,
    not let one NaN factor poison the whole solve. ``remap[l, b]`` is the
    nearest valid level ≥ l (the controller only ever moves up the ladder),
    falling back to the largest valid level below when the top of the
    ladder is invalid, and −1 when the problem has NO valid level at all
    (its lattice verdict is ``LEVEL_INVALID``). Both sweeps are one
    associative scan over the ladder axis — O(L·B), free next to the
    factorizations themselves."""
    L = level_ok.shape[0]
    idx = jnp.arange(L, dtype=jnp.int32)[:, None]
    up = jnp.where(level_ok, idx, jnp.int32(L))
    up = jax.lax.associative_scan(jnp.minimum, up, reverse=True, axis=0)
    down = jnp.where(level_ok, idx, jnp.int32(-1))
    down = jax.lax.associative_scan(jnp.maximum, down, axis=0)
    remap = jnp.where(up < L, up, down)          # (L, B); −1 ⇒ none valid
    return remap, jnp.any(level_ok, axis=0)


# ---------------------------------------------------------------------------
# Solve pieces: ladder precompute → init state → segment loop → finalize.
# All traceable; the public jitted entry points below compose them.
# ---------------------------------------------------------------------------

def _compute_ladder_grams(q, keys, *, m_max, sketch, mesh, compute_dtype):
    """(L, B, d, d) ladder-level Grams — the ONE touch of A."""
    provider = get_provider(sketch)
    ladder = doubling_ladder(m_max)
    if mesh is None:
        data = provider.sample(keys, m_max, q.n, _field_dtype(q))
        return provider.level_grams(data, q, ladder,
                                    compute_dtype=compute_dtype)
    from .distributed import shard_level_grams

    return shard_level_grams(provider, keys, q, ladder, mesh,
                             compute_dtype=compute_dtype)


def _ladder_tables(q: Quadratic, grams: jnp.ndarray, *, guards: bool):
    """Factorize the ladder and build the guard tables from level Grams.
    Returns (pinvs, remap, any_valid, gram_poisoned, invalid_levels);
    with ``guards=False`` the remap is the identity and validity is
    assumed (the pre-guard hot path, byte-identical gathers)."""
    B = q.batch
    pinvs = _precompute_pinvs(grams, q)
    L = pinvs.shape[0]
    if not guards:
        remap = jnp.broadcast_to(
            jnp.arange(L, dtype=jnp.int32)[:, None], (L, B))
        return (pinvs, remap, jnp.ones((B,), bool),
                jnp.zeros((B,), bool), jnp.zeros((B,), jnp.int32))
    # Post-Cholesky validity: a level is usable only if its Gram and its
    # factorized inverse are entirely finite. Invalid levels are skipped
    # via the remap (gathers below go through the redirected table);
    # problems with NO valid level get identity "inverses" so their lanes
    # stay finite — they are frozen at x₀ before the loop and reported
    # LEVEL_INVALID.
    gram_ok = jnp.all(jnp.isfinite(grams), axis=(-1, -2))           # (L, B)
    level_ok = gram_ok & jnp.all(jnp.isfinite(pinvs), axis=(-1, -2))
    # non-finite Grams mean poisoned data or a poisoned sketch pass —
    # distinguishes NAN_POISONED from the finite-but-singular
    # LEVEL_INVALID verdict when the whole ladder is unusable
    gram_poisoned = jnp.any(~gram_ok, axis=0)                       # (B,)
    remap, any_valid = _valid_level_remap(level_ok)
    pinvs = jnp.take_along_axis(
        pinvs, jnp.maximum(remap, 0)[:, :, None, None], axis=0)
    pinvs = jnp.where(any_valid[None, :, None, None], pinvs,
                      jnp.eye(q.d, dtype=pinvs.dtype))
    invalid_levels = jnp.sum(~level_ok, axis=0).astype(jnp.int32)
    return pinvs, remap, any_valid, gram_poisoned, invalid_levels


def _gram_precompute(q: Quadratic, gram_hvp: bool | None, mesh):
    """The optional true-Gram precompute behind ``gram_hvp`` (None = auto:
    on when d ≤ min(n, 1024)). Returns the (d, d) / (B, d, d) Gram, or
    None for the matrix-free hvp."""
    if gram_hvp is None:
        gram_hvp = q.d <= min(q.n, 1024)
    if not gram_hvp:
        return None
    w = q.row_weights
    if w is not None:
        # AᵀWA once, via the chunked streaming Gram (or its sharded psum
        # variant) — per-problem even with shared A, and never through an
        # (n, d) weighted copy of A
        if mesh is None:
            return weighted_gram(q.A, w)                 # (B, d, d)
        from .distributed import shard_weighted_gram

        return shard_weighted_gram(q, mesh)
    if q.shared_A:
        return q.A.T @ q.A                               # (d, d) once
    return jnp.einsum("bnd,bne->bde", q.A, q.A)          # (B, d, d) once


def _hvp_fn(q: Quadratic, G_full):
    """H·v under the precomputed Gram (or q's matrix-free hvp)."""
    if G_full is None:
        return q.hvp
    if G_full.ndim == 2:
        return lambda v: v @ G_full + (q.nu**2)[:, None] * q.lam_diag * v
    return lambda v: jnp.einsum("bde,be->bd", G_full, v) + (
        (q.nu**2)[:, None] * q.lam_diag * v)


def _init_padded_state(q: Quadratic, pre: PaddedPrecompute,
                       init_level, tol, x0=None) -> PaddedState:
    B, d = q.batch, q.d
    fdtype = _field_dtype(q)
    top = pre.remap.shape[0] - 1
    grad_f = lambda x: _hvp_fn(q, pre.G_full)(x) - q.b

    if init_level is None:
        lvl0 = jnp.zeros((B,), jnp.int32)
    else:
        lvl0 = jnp.clip(init_level.astype(jnp.int32), 0, top)
    pinv0 = _gather_pinv(pre.pinvs, lvl0)
    if x0 is None:
        x0 = jnp.zeros((B, d), fdtype)
        g0 = grad_f(x0)                              # = −b
        rt0 = _apply_pinv(pinv0, -g0)
        dtw = 0.5 * _pdot(-g0, rt0)
        dt0 = dtw
        conv0 = dt0 <= tol * dt0                     # trivially-solved (b=0)
    else:
        # Warm start (path mode, DESIGN.md §13): anchor the state at x0,
        # but keep the convergence scale dtilde0 at the COLD b-based δ̃(0)
        # so tol stays relative to the problem, not to how good the warm
        # start already is — the same anchor ``do_refactor`` re-derives
        # after a doubling. A warm start good enough to clear tol·δ̃(0)
        # converges before the loop runs a single trip.
        x0 = x0.astype(fdtype)
        g0 = grad_f(x0)
        rt0 = _apply_pinv(pinv0, -g0)
        dtw = 0.5 * _pdot(-g0, rt0)
        dt0 = 0.5 * _pdot(q.b, _apply_pinv(pinv0, q.b))
        conv0 = dtw <= tol * dt0

    return PaddedState(
        x=x0, x_prev=x0, r=-g0, rt=rt0, p=rt0, grad=g0,
        level=lvl0, t_rel=jnp.zeros((B,), jnp.int32),
        dtilde_I=dtw, dtilde=dtw, dtilde0=dt0,
        x_best=x0, dt_best=dtw, pinv=pinv0,
        iters=jnp.zeros((B,), jnp.int32),
        doublings=jnp.zeros((B,), jnp.int32),
        done=conv0 | ~pre.any_valid,     # no valid level ⇒ frozen at x₀
        converged=conv0,
        nan_hit=jnp.zeros((B,), bool),
        trips=jnp.asarray(0, jnp.int32),
    )


def _run_segment(q: Quadratic, pre: PaddedPrecompute, st: PaddedState,
                 trip_limit, *, method: str, max_iters: int, rho: float,
                 tol, guards: bool) -> PaddedState:
    """The adaptive while_loop, bounded by ``trip_limit`` (a TRACED trip
    count: the segmented driver re-dispatches this same executable with the
    limit advanced by k per segment; the monolithic solve pins it at the
    trip cap). The body is identical either way, so segment boundaries
    never change the numbers — a segmented solve is bitwise the monolithic
    one."""
    hvp = _hvp_fn(q, pre.G_full)
    grad_f = lambda x: hvp(x) - q.b
    fdtype = _field_dtype(q)
    top = pre.remap.shape[0] - 1

    phi, alpha = rho_to_rate(method, rho)
    c = c_alpha_rho(alpha, rho)
    mu = 1.0 - rho
    # Polyak heavy-ball constants (Appendix A), matching core.solvers
    _sq = math.sqrt(1.0 - rho)
    mu_p = 2.0 * (1.0 - rho) / (1.0 + _sq)
    beta_p = (1.0 - _sq) / (1.0 + _sq)

    def cond(st: PaddedState):
        return (~jnp.all(st.done)) & (st.trips < trip_limit)

    def body(st: PaddedState) -> PaddedState:
        active = ~st.done
        pinv = st.pinv
        # ---- one step of the method under the current preconditioner ----
        if method in ("ihs", "polyak"):
            # rt caches H_S⁻¹(b − Hx) = −H_S⁻¹∇f from the previous trip's
            # δ̃ evaluation (or the restart), so each trip applies the
            # preconditioner once, not twice. Polyak adds the heavy-ball
            # momentum β(x − x_prev); x_prev resets on every restart.
            if method == "ihs":
                x_new = st.x + mu * st.rt
            else:
                x_new = st.x + mu_p * st.rt + beta_p * (st.x - st.x_prev)
            g_new = grad_f(x_new)
            rt_new = _apply_pinv(pinv, -g_new)
            dt_new = 0.5 * _pdot(-g_new, rt_new)
            r_new, p_new = -g_new, st.p
        else:  # pcg
            Hp = hvp(st.p)
            denom = _pdot(st.p, Hp)
            ok = denom > 0
            alpha_s = jnp.where(ok, 2.0 * st.dtilde / jnp.where(ok, denom, 1.0), 0.0)
            x_new = st.x + alpha_s[:, None] * st.p
            r_new = st.r - alpha_s[:, None] * Hp
            rt_new = _apply_pinv(pinv, r_new)
            dt_new = 0.5 * _pdot(r_new, rt_new)
            okb = st.dtilde > 0
            beta = jnp.where(okb, dt_new / jnp.where(okb, st.dtilde, 1.0), 0.0)
            p_new = rt_new + beta[:, None] * st.p
            g_new = -r_new

        # ---- per-problem improvement test (Alg 4.1 line 6) ----
        threshold = c * (phi ** (st.t_rel + 1).astype(fdtype)) * st.dtilde_I
        if guards:
            # a proposal is only acceptable if the iterate itself is finite,
            # not just its δ̃ — the pair (Inf, −Inf) can produce a finite
            # inner product, and an accepted non-finite x would defeat the
            # best-finite-iterate guarantee below
            finite_prop = jnp.isfinite(dt_new) & jnp.all(
                jnp.isfinite(x_new), axis=-1)
        else:
            finite_prop = jnp.isfinite(dt_new)
        bad = ~finite_prop | (dt_new > threshold)
        at_cap = st.level >= top
        reject = bad & active & ~at_cap
        # At the ladder cap the rate test is unenforceable (no further
        # doubling), so steps are accepted freely and the BEST iterate is
        # tracked instead: f32 δ̃-floor oscillation polishes harmlessly,
        # while clear divergence (a divergent method under a too-weak
        # capped preconditioner, e.g. IHS) stalls the problem — the caller
        # reads the shortfall off the returned δ̃ certificate. Without the
        # safeguard a diverging iteration would be "accepted" to overflow.
        # A non-finite proposal at the cap is the per-problem circuit
        # breaker: the problem freezes at its best finite iterate (a
        # non-finite proposal is NEVER accepted, so x_best stays finite for
        # finite inputs) and ``nan_hit`` records the poisoning for the
        # status verdict.
        stalled = active & at_cap & (
            ~finite_prop | (dt_new > 1e6 * st.dt_best))
        accept = active & ~reject & ~stalled
        conv_now = accept & (dt_new <= tol * st.dtilde0)

        aB = accept[:, None]
        improved = accept & (dt_new < st.dt_best)
        st1 = PaddedState(
            x=jnp.where(aB, x_new, st.x),
            x_prev=jnp.where(aB, st.x, st.x_prev),
            r=jnp.where(aB, r_new, st.r),
            rt=jnp.where(aB, rt_new, st.rt),
            p=jnp.where(aB, p_new, st.p),
            grad=jnp.where(aB, g_new, st.grad),
            level=jnp.where(reject, jnp.minimum(st.level + 1, top), st.level),
            t_rel=jnp.where(accept, st.t_rel + 1, st.t_rel),
            dtilde_I=st.dtilde_I,
            dtilde=jnp.where(accept, dt_new, st.dtilde),
            dtilde0=st.dtilde0,
            x_best=jnp.where(improved[:, None], x_new, st.x_best),
            dt_best=jnp.where(improved, dt_new, st.dt_best),
            pinv=st.pinv,
            iters=st.iters + accept.astype(jnp.int32),
            doublings=st.doublings + reject.astype(jnp.int32),
            done=st.done | stalled | conv_now
                 | (st.iters + accept.astype(jnp.int32) >= max_iters),
            converged=st.converged | conv_now,
            nan_hit=st.nan_hit | (active & ~finite_prop),
            trips=st.trips + 1,
        )

        def do_refactor(s: PaddedState) -> PaddedState:
            # Doubling: unmask more rows + restart at the current iterate
            # (Alg 4.1 line 8). "Refactorization" is a pure gather of the
            # precomputed per-level inverses (problems whose level did not
            # change get the identical factor back); the restart residual
            # is the stored gradient (x did not move on a reject), so no
            # extra H·v is needed.
            pinv_new = _gather_pinv(pre.pinvs, s.level)
            res = -s.grad                              # b − Hx at current x
            rt_re = _apply_pinv(pinv_new, res)
            dt_re = 0.5 * _pdot(res, rt_re)
            dt0_re = 0.5 * _pdot(q.b, _apply_pinv(pinv_new, q.b))
            rB = reject[:, None]
            return s._replace(
                pinv=pinv_new,
                r=jnp.where(rB, res, s.r),
                rt=jnp.where(rB, rt_re, s.rt),
                p=jnp.where(rB, rt_re, s.p),
                x_prev=jnp.where(rB, s.x, s.x_prev),   # momentum restart
                t_rel=jnp.where(reject, 0, s.t_rel),
                # δ̃ is metric-dependent: restart best-tracking in the new
                # preconditioner's metric at the current iterate
                x_best=jnp.where(rB, s.x, s.x_best),
                dt_best=jnp.where(reject, dt_re, s.dt_best),
                dtilde_I=jnp.where(reject, dt_re, s.dtilde_I),
                dtilde=jnp.where(reject, dt_re, s.dtilde),
                dtilde0=jnp.where(reject, dt0_re, s.dtilde0),
            )

        return jax.lax.cond(jnp.any(reject), do_refactor, lambda s: s, st1)

    return jax.lax.while_loop(cond, body, st)


def _finalize(pre: PaddedPrecompute, st: PaddedState, *, m_max: int):
    """Status lattice + certificates from the terminal (or paused) state."""
    ladder_m = jnp.asarray(doubling_ladder(m_max), jnp.int32)
    B = pre.remap.shape[1]
    # report the level actually used (the remapped gather target), so
    # m_final and warm-start tokens reflect the sketch that produced the
    # certificate rather than a skipped invalid level
    eff_level = jnp.maximum(
        pre.remap[st.level, jnp.arange(B)], 0).astype(jnp.int32)
    status = jnp.where(
        st.converged, jnp.int32(SolveStatus.OK),
        jnp.where(st.nan_hit | pre.gram_poisoned,
                  jnp.int32(SolveStatus.NAN_POISONED),
                  jnp.where(~pre.any_valid,
                            jnp.int32(SolveStatus.LEVEL_INVALID),
                            jnp.int32(SolveStatus.STALLED))))
    stats = {"m_final": ladder_m[eff_level], "iters": st.iters,
             "doublings": st.doublings, "dtilde": st.dt_best,
             "level": eff_level, "trips": st.trips,
             "status": status, "converged": st.converged,
             "stalled": status == jnp.int32(SolveStatus.STALLED),
             "invalid_levels": pre.invalid_levels}
    return st.x_best, stats


# ---------------------------------------------------------------------------
# Public jitted entry points
# ---------------------------------------------------------------------------

@partial(jax.jit,
         static_argnames=("m_max", "sketch", "gram_hvp", "mesh", "guards",
                          "compute_dtype"))
def prepare_padded_solve(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    sketch: str = "gaussian",
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    guards: bool = True,
    compute_dtype: str = "fp32",
    tol: float = 1e-10,
    grams: jnp.ndarray | None = None,
    gram_full: jnp.ndarray | None = None,
    x0: jnp.ndarray | None = None,
):
    """Everything before the loop, as one jitted dispatch: the one-touch
    ladder pass (or ``grams=`` to supply precomputed/recombined level Grams
    — the elastic-recovery path feeds a ``distributed.ShardLadderCache``
    total here, and the path engine the shared λ-free ladder of
    ``prepare_path_ladder``), the batched factorizations + guard tables,
    the optional true-Gram precompute (or ``gram_full=`` to supply it) and
    the initial state — at the origin, or at a warm-start iterate ``x0=``
    (B, d). Returns ``(PaddedPrecompute, PaddedState)`` — both plain-array
    pytrees; the state is what checkpoints persist, the precompute is
    deterministic given (q, keys) and is recomputed on resume."""
    if not q.batched:
        raise ValueError("prepare_padded_solve expects a batched Quadratic")
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)
    compute_dtype = canonical_compute_dtype(compute_dtype)
    if grams is None:
        grams = _compute_ladder_grams(q, keys, m_max=m_max, sketch=sketch,
                                      mesh=mesh, compute_dtype=compute_dtype)
    pinvs, remap, any_valid, gram_poisoned, invalid_levels = _ladder_tables(
        q, grams, guards=guards)
    if gram_full is None:
        gram_full = _gram_precompute(q, gram_hvp, mesh)
    pre = PaddedPrecompute(
        pinvs=pinvs, remap=remap, any_valid=any_valid,
        gram_poisoned=gram_poisoned, invalid_levels=invalid_levels,
        G_full=gram_full)
    return pre, _init_padded_state(q, pre, init_level, tol, x0=x0)


@partial(jax.jit, static_argnames=("method", "max_iters", "rho", "guards"),
         donate_argnames=("st",))
def padded_solve_segment(
    q: Quadratic,
    pre: PaddedPrecompute,
    st: PaddedState,
    trip_limit,
    *,
    method: str = "ihs",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    guards: bool = True,
) -> PaddedState:
    """Advance the adaptive loop to ``trip_limit`` total trips (a traced
    int32 scalar — ONE compiled executable serves every segment size and
    every resume point). State round-trips losslessly, so dispatching
    k-trip segments back-to-back is bitwise the monolithic while_loop.

    ``st`` is DONATED: the 20-field state aliases its output buffers, so a
    long segmented solve holds one state's worth of memory instead of two
    per dispatch. Callers must treat the passed state as consumed — the
    host driver (``core.robust``) rebinds it on every segment; anything a
    checkpoint persists is read from the *returned* state."""
    if method not in PADDED_METHODS:
        raise ValueError(
            f"padded engine supports {PADDED_METHODS}, got {method!r}")
    return _run_segment(q, pre, st, jnp.asarray(trip_limit, jnp.int32),
                        method=method, max_iters=max_iters, rho=rho,
                        tol=tol, guards=guards)


@partial(jax.jit, static_argnames=("m_max",))
def finalize_padded_solve(pre: PaddedPrecompute, st: PaddedState, *,
                          m_max: int):
    """(x_best, stats) from a terminal — or deadline-paused — state; the
    certificates (δ̃, m_final, level) describe the best finite iterate
    actually reached, which is what an honest DEADLINE_EXCEEDED answer
    returns."""
    return _finalize(pre, st, m_max=m_max)


@partial(jax.jit, static_argnames=("guards",))
def reprecondition_padded(
    q: Quadratic,
    pre: PaddedPrecompute,
    st: PaddedState,
    grams: jnp.ndarray,
    *,
    guards: bool = True,
):
    """Rebuild the ladder from replacement level Grams MID-SOLVE and
    re-anchor every unfinished problem at its current iterate — the elastic
    shard-recovery step (DESIGN.md §11).

    After a data shard drops, the surviving per-shard level-Gram
    contributions recombine by one subtraction (``ShardLadderCache``);
    this refactors the recombined ladder (batched Cholesky + guard tables,
    exactly the prepare-time path) and then mirrors the in-loop doubling
    restart for every not-done problem: regather H_S⁻¹ at its current
    level, recompute r/r̃/p and the δ̃ anchors from the stored gradient,
    and restart best-iterate tracking in the new metric at the current x.
    The true Hessian (``pre.G_full`` / q) is untouched — the solve still
    targets the ORIGINAL problem exactly; only the preconditioner weakens —
    so a subsequent convergence is an honest ``OK`` with a truthful δ̃.
    Problems already done keep their iterates and verdicts bit-for-bit."""
    pinvs, remap, any_valid2, gram_poisoned2, invalid2 = _ladder_tables(
        q, grams, guards=guards)
    # validity composes: a problem frozen by the OLD ladder never iterated
    # (and must stay LEVEL_INVALID); one with no valid level in the NEW
    # ladder freezes now at its best finite iterate
    any_valid = pre.any_valid & any_valid2
    pre2 = PaddedPrecompute(
        pinvs=pinvs, remap=remap, any_valid=any_valid,
        gram_poisoned=pre.gram_poisoned | gram_poisoned2,
        invalid_levels=jnp.maximum(pre.invalid_levels, invalid2),
        G_full=pre.G_full)
    active = ~st.done
    pinv_new = _gather_pinv(pinvs, st.level)
    res = -st.grad                                 # b − Hx at the current x
    rt = _apply_pinv(pinv_new, res)
    dt = 0.5 * _pdot(res, rt)
    dt0 = 0.5 * _pdot(q.b, _apply_pinv(pinv_new, q.b))
    aB = active[:, None]
    st2 = st._replace(
        pinv=jnp.where(active[:, None, None], pinv_new, st.pinv),
        r=jnp.where(aB, res, st.r),
        rt=jnp.where(aB, rt, st.rt),
        p=jnp.where(aB, rt, st.p),
        x_prev=jnp.where(aB, st.x, st.x_prev),     # momentum restart
        t_rel=jnp.where(active, 0, st.t_rel),
        x_best=jnp.where(aB, st.x, st.x_best),
        dt_best=jnp.where(active, dt, st.dt_best),
        dtilde_I=jnp.where(active, dt, st.dtilde_I),
        dtilde=jnp.where(active, dt, st.dtilde),
        dtilde0=jnp.where(active, dt0, st.dtilde0),
        done=st.done | (active & ~any_valid),
    )
    return pre2, st2


@partial(jax.jit,
         static_argnames=("m_max", "method", "sketch", "max_iters", "rho",
                          "gram_hvp", "mesh", "guards", "compute_dtype"))
def padded_adaptive_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    method: str = "ihs",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    guards: bool = True,
    compute_dtype: str = "fp32",
    grams: jnp.ndarray | None = None,
    gram_full: jnp.ndarray | None = None,
    x0: jnp.ndarray | None = None,
):
    """One-executable adaptive solve of a batch of B problems.

    ``q`` must be batched (per-problem A (B,n,d) or shared A (n,d));
    ``keys`` is a single PRNG key (split internally) or a (B,)-batch of keys
    — problem b's sketch depends only on keys[b]. Returns (x, stats) with
    x (B, d) and per-problem stats vectors (m_final, iters, doublings, δ̃,
    and the final ladder ``level`` index — what a warm restart passes back).

    ``q.row_weights`` (B, n) solves the *weighted* problem
    H = AᵀWA + ν²Λ: the providers sketch W^{1/2}A inside their one
    streaming pass (scaling generated S tiles / sign streams by w^{1/2} —
    never an (n, d) weighted copy of A, DESIGN.md §8) and the hvp applies
    the weight on the (B, n) intermediate. This is the GLM Newton
    subproblem layout (``core.newton``).

    ``init_level`` (B,) int32 starts each problem's doubling ladder at the
    given level instead of 0 — the warm-started m_t of the adaptive Newton
    sketch (arXiv:2105.07291): a Newton driver passes the previous outer
    step's final level so the inner solve does not re-climb the ladder it
    already discovered. Values are clipped to the ladder; a traced array,
    so warm restarts reuse the same executable.

    ``gram_hvp`` (default: auto, on when d ≤ min(n, 1024)): precompute the
    per-problem Gram AᵀA once so every in-loop H·v is a (B,d,d)·(B,d)
    matvec instead of two memory-bound (B,n,d) GEMVs — the right trade in
    the serving regime (n ≫ d, many iterations), and no more than the
    sketch pass we already pay; large-d problems keep the matrix-free O(nd)
    hvp of the paper.

    ``guards`` (static, default on): the failure-isolation layer
    (DESIGN.md §9). Post-Cholesky finiteness checks mark individual ladder
    levels invalid and the controller *skips* them (``_valid_level_remap``)
    instead of letting one NaN factor poison the solve; iterate proposals
    are finiteness-checked so a non-finite step is rejected (doubling below
    the cap, circuit-breaking at it) and the best FINITE iterate is always
    what is returned; every problem exits with a truthful per-problem
    ``status`` ∈ {OK, STALLED, LEVEL_INVALID, NAN_POISONED} plus explicit
    ``converged``/``stalled`` flags. ``guards=False`` restores the
    pre-guard hot path (no level remap, δ̃-only finiteness) for overhead
    benchmarking (``benchmarks/bench_guard.py``); statuses are still
    reported but ladder validity is assumed.

    ``compute_dtype`` (static, ``kernels.precision``): precision of the
    one-touch sketch pass only — ``"bf16"`` streams/contracts sketch
    operands in bfloat16 with fp32 accumulation, ``"int8"`` additionally
    quantizes A per row and streams the codes. The (L, B, d, d) ladder
    Grams, their Cholesky factors, every in-loop quantity and the δ̃
    certificates are fp32 in all modes, so guards and the certificate
    contract are unchanged; the sketch is merely a (slightly) noisier
    spectral approximation, which the doubling controller absorbs
    (DESIGN.md §10). The fp32 default is bit-identical to the
    pre-dtype-axis engine.

    ``mesh`` (static): a ``jax.sharding.Mesh`` whose data axes row-shard A
    (``distributed.shard_quadratic`` places it). The ONLY thing that
    changes is the precompute: the one-touch ladder pass runs per shard
    with independent per-shard randomness and combines the (L, B, d, d)
    level Grams in ONE psum (``distributed.shard_level_grams``,
    DESIGN.md §5); the while_loop is byte-identical, operating on the
    replicated d-sized state. With ``gram_hvp`` (the serving default) the
    AᵀA precompute is the only other data-axis collective and the loop
    itself is collective-free; matrix-free mode keeps one psum(B·d) per
    hvp, inserted by GSPMD.

    ``grams`` / ``gram_full`` / ``x0`` (traced, path mode — DESIGN.md §13):
    supply a precomputed λ-free ladder of level Grams (L, B, d, d), the
    precomputed true Gram, and/or a warm-start iterate (B, d). With
    ``grams=`` the one-touch sketch pass is SKIPPED — the λ sweep of
    ``padded_path_solve_batched`` pays it once via ``prepare_path_ladder``
    and re-solves every λ point off the shared ladder, with only the
    ν²Λ-shifted factorizations repeated per point.

    This function is ``prepare_padded_solve`` → ``padded_solve_segment``
    (with the trip limit pinned at the trip cap) → ``finalize_padded_solve``
    composed in one jit — bit-identical to dispatching the segments
    separately (``core.robust.segmented_padded_solve_batched``, the
    preemptible/deadline-aware host driver).
    """
    if not q.batched:
        raise ValueError("use padded_adaptive_solve for single problems")
    if method not in PADDED_METHODS:
        raise ValueError(f"padded engine supports {PADDED_METHODS}, got {method!r}")
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)
    compute_dtype = canonical_compute_dtype(compute_dtype)
    if grams is None:
        grams = _compute_ladder_grams(q, keys, m_max=m_max, sketch=sketch,
                                      mesh=mesh, compute_dtype=compute_dtype)
    pinvs, remap, any_valid, gram_poisoned, invalid_levels = _ladder_tables(
        q, grams, guards=guards)
    if gram_full is None:
        gram_full = _gram_precompute(q, gram_hvp, mesh)
    pre = PaddedPrecompute(
        pinvs=pinvs, remap=remap, any_valid=any_valid,
        gram_poisoned=gram_poisoned, invalid_levels=invalid_levels,
        G_full=gram_full)
    init = _init_padded_state(q, pre, init_level, tol, x0=x0)
    st = _run_segment(q, pre, init, padded_trip_cap(m_max, max_iters),
                      method=method, max_iters=max_iters, rho=rho, tol=tol,
                      guards=guards)
    return _finalize(pre, st, m_max=m_max)


@partial(jax.jit,
         static_argnames=("m_max", "sketch", "gram_hvp", "mesh",
                          "compute_dtype"))
def prepare_path_ladder(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    sketch: str = "gaussian",
    gram_hvp: bool | None = None,
    mesh=None,
    compute_dtype: str = "fp32",
):
    """The λ-FREE precompute shared by an entire regularization path: the
    one-touch ladder pass (under ``mesh``, the same per-shard pass + ONE
    psum of the (L, B, d, d) level Grams) plus the optional true-Gram
    precompute. Neither output reads q.nu / q.lam_diag — the ν²Λ shift
    enters only at factorization (``precond.shifted_ladder_inverses``) —
    so the returned ``(grams, gram_full)`` pair serves EVERY λ point of a
    grid: feed it to ``prepare_padded_solve`` / the batched solver via
    ``grams=`` / ``gram_full=`` (DESIGN.md §13). This is also the unit the
    serving ladder cache stores per (A, Λ, family, dtype) fingerprint.

    ``gram_full`` is None when the hvp stays matrix-free (``gram_hvp``
    auto-off for large d) — pass the pair through unchanged either way."""
    if not q.batched:
        raise ValueError("prepare_path_ladder expects a batched Quadratic")
    if _is_single_key(keys):
        keys = jax.random.split(keys, q.batch)
    compute_dtype = canonical_compute_dtype(compute_dtype)
    grams = _compute_ladder_grams(q, keys, m_max=m_max, sketch=sketch,
                                  mesh=mesh, compute_dtype=compute_dtype)
    return grams, _gram_precompute(q, gram_hvp, mesh)


def padded_path_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    nus: jnp.ndarray,
    *,
    m_max: int,
    method: str = "ihs",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    guards: bool = True,
    compute_dtype: str = "fp32",
    warm_start: bool = True,
):
    """Regularization-path solve: the full λ grid off ONE sketch pass.

    ``q`` is a batched Quadratic (B problems; its own ``q.nu`` is ignored)
    and ``nus`` is the λ grid — (P,) shared across the batch, or (P, B)
    per-problem. Because the ladder-level Grams are λ-free, the one-touch
    sketch pass (and the optional true-Gram precompute) runs ONCE via
    ``prepare_path_ladder``; each grid point then pays only the ν²Λ-shifted
    factorizations (``precond.shifted_ladder_inverses``) and its solve —
    a P-point path costs ~1 sketch pass instead of P (DESIGN.md §13).

    ``warm_start`` (default on) carries both the iterate x AND the
    per-problem sketch level from the previous grid point: point p+1
    starts at x_p with ``init_level`` = the final ladder level of point p
    (the traced warm-start hook), so a grid walked from strong to weak
    regularization never re-climbs the ladder — level trajectories are
    monotone along the path. The convergence scale stays each point's
    cold δ̃(0), so certificates mean the same thing warm or cold.
    ``init_level`` seeds the FIRST point (e.g. from a previous path).

    Each point is solved by ``padded_adaptive_solve_batched`` with
    ``grams=`` / ``gram_full=`` supplied, so per-point numbers are
    bit-identical to a single-λ solve handed the same shared ladder,
    warm start and init level; ``guards`` semantics are per point.

    Returns ``(xs, stats)``: xs (P, B, d) and stats with the per-point
    engine vectors stacked to (P, B) (``trips`` to (P,)), plus
    ``sketch_passes`` = 1 — the whole grid touched A once."""
    if not q.batched:
        raise ValueError("padded_path_solve_batched expects a batched "
                         "Quadratic")
    fdtype = _field_dtype(q)
    nus = jnp.asarray(nus, fdtype)
    if nus.ndim == 1:
        nus = jnp.broadcast_to(nus[:, None], (nus.shape[0], q.batch))
    P = nus.shape[0]
    if _is_single_key(keys):
        keys = jax.random.split(keys, q.batch)
    grams, gram_full = prepare_path_ladder(
        q, keys, m_max=m_max, sketch=sketch, gram_hvp=gram_hvp, mesh=mesh,
        compute_dtype=compute_dtype)
    xs, per_point = [], []
    x_prev, lvl = None, init_level
    for p in range(P):
        q_p = dataclasses.replace(q, nu=nus[p])
        x, stats = padded_adaptive_solve_batched(
            q_p, keys, m_max=m_max, method=method, sketch=sketch,
            max_iters=max_iters, rho=rho, tol=tol, gram_hvp=gram_hvp,
            mesh=mesh, init_level=lvl, guards=guards,
            compute_dtype=compute_dtype, grams=grams, gram_full=gram_full,
            x0=x_prev)
        xs.append(x)
        per_point.append(stats)
        if warm_start:
            x_prev, lvl = x, stats["level"]
    out = {k: jnp.stack([s[k] for s in per_point]) for k in per_point[0]}
    out["sketch_passes"] = 1
    return jnp.stack(xs), out


def padded_adaptive_solve(
    q: Quadratic,
    key: jax.Array,
    *,
    m_max: int,
    method: str = "ihs",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    compute_dtype: str = "fp32",
):
    """Adaptive solve of one problem as a B=1 (or B=c for matrix RHS) batch
    through the padded multi-problem engine. Returns (x, stats) with scalar
    stats for vector right-hand sides; a (d, c) matrix RHS is dispatched as
    a shared-A batch over columns and gets per-column stats."""
    if q.batched:
        return padded_adaptive_solve_batched(
            q, key, m_max=m_max, method=method, sketch=sketch,
            max_iters=max_iters, rho=rho, tol=tol,
            compute_dtype=compute_dtype)
    matrix_rhs = q.b.ndim == 2
    if matrix_rhs:
        B = q.b.shape[1]
        b = q.b.T
        keys = jax.random.split(key, B)
    else:
        B = 1
        b = q.b[None, :]
        keys = key[None] if _is_single_key(key) else key
    nu = jnp.broadcast_to(jnp.atleast_1d(q.nu), (B,))
    lam = jnp.broadcast_to(q.lam_diag, (B, q.d))
    w = (None if q.row_weights is None
         else jnp.broadcast_to(q.row_weights, (B, q.n)))
    qb = Quadratic(A=q.A, b=b, nu=nu, lam_diag=lam, batched=True,
                   row_weights=w)
    x, stats = padded_adaptive_solve_batched(
        qb, keys, m_max=m_max, method=method, sketch=sketch,
        max_iters=max_iters, rho=rho, tol=tol,
        compute_dtype=compute_dtype)
    if matrix_rhs:
        return x.T, stats
    return x[0], {k: (v[0] if getattr(v, "ndim", 0) else v)
                  for k, v in stats.items()}
