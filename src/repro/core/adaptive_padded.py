"""Beyond-paper: fully-jitted adaptive solver with a *padded* sketch.

The paper's Algorithm 4.1 changes the sketch shape at runtime (m doubles),
which forces either recompilation per size or host orchestration
(``core.adaptive``). In serving/TPU environments with fixed-shape
executables, we instead:

* allocate the sketch at a maximum size m_max once;
* keep an *active-row count* m_t as a traced integer; rows ≥ m_t are masked
  to zero and the live rows are rescaled by √(m_max/m_t) so the masked
  sketch has exactly the law of an m_t-row sketch (for Gaussian/SJLT whose
  rows are i.i.d.);
* run the whole adaptive loop as one ``lax.while_loop`` — the improvement
  test, doubling (m_t ← 2·m_t, i.e. unmask more rows) and refactorization
  are all inside the compiled graph.

Cost trade-off vs the paper: every refactorization pays the m_max-shape
Gram/Cholesky cost (we cannot shrink shapes in-graph), but there are at
most log₂(m_max) of them; in exchange there is exactly ONE executable and
no host round-trips — the right trade on real TPU pods where launch
latency and recompiles dominate at small m. Recorded in EXPERIMENTS.md.

Gaussian sketch only (i.i.d. rows ⇒ masking = subsampling). IHS inner
update (the test thresholds follow Thm 3.2: φ(ρ)=ρ, α=1).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .quadratic import Quadratic
from .solvers import c_alpha_rho


class PaddedState(NamedTuple):
    x: jnp.ndarray
    m: jnp.ndarray            # active rows (traced int32)
    t_rel: jnp.ndarray        # iterations since last restart
    dtilde_I: jnp.ndarray     # δ̃ at last restart
    dtilde: jnp.ndarray       # current δ̃
    chol: jnp.ndarray         # (d, d) Cholesky of H_S (primal form)
    iters: jnp.ndarray        # accepted iterations
    doublings: jnp.ndarray


def _masked_factorize(q: Quadratic, S: jnp.ndarray, m: jnp.ndarray):
    """Cholesky of H_S for the m-row masked/rescaled sketch (fixed shapes)."""
    m_max = S.shape[0]
    mask = (jnp.arange(m_max) < m).astype(S.dtype)
    scale = jnp.sqrt(jnp.asarray(m_max, S.dtype) / jnp.maximum(m, 1).astype(S.dtype))
    SA = (S * (mask * scale)[:, None]) @ q.A
    H_S = SA.T @ SA + jnp.diag((q.nu**2) * q.lam_diag)
    return jnp.linalg.cholesky(H_S)


def _chol_solve(chol, z):
    y = jax.scipy.linalg.solve_triangular(chol, z, lower=True)
    return jax.scipy.linalg.solve_triangular(chol.T, y, lower=False)


@partial(jax.jit, static_argnames=("m_max", "max_iters", "rho"))
def padded_adaptive_solve(
    q: Quadratic,
    key: jax.Array,
    *,
    m_max: int,
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
):
    """One-executable adaptive IHS. Returns (x, stats dict)."""
    d = q.d
    S = jax.random.normal(key, (m_max, q.n), dtype=q.A.dtype) / jnp.sqrt(
        jnp.asarray(m_max, q.A.dtype)
    )
    phi, alpha = rho, 1.0
    c = c_alpha_rho(alpha, rho)
    mu = 1.0 - rho

    x0 = jnp.zeros_like(q.b)
    m0 = jnp.asarray(1, jnp.int32)
    chol0 = _masked_factorize(q, S, m0)
    g0 = q.grad(x0)
    dt0 = 0.5 * jnp.sum(g0 * _chol_solve(chol0, g0))

    init = PaddedState(
        x=x0, m=m0, t_rel=jnp.asarray(0, jnp.int32), dtilde_I=dt0, dtilde=dt0,
        chol=chol0, iters=jnp.asarray(0, jnp.int32),
        doublings=jnp.asarray(0, jnp.int32),
    )
    dt_ref = dt0  # reference for the relative stop (updated on resketch)

    def cond(carry):
        st, dt_ref = carry
        return (st.iters < max_iters) & (st.dtilde > tol * dt_ref)

    def body(carry):
        st, dt_ref = carry
        g = q.grad(st.x)
        x_new = st.x - mu * _chol_solve(st.chol, g)
        g_new = q.grad(x_new)
        dt_new = 0.5 * jnp.sum(g_new * _chol_solve(st.chol, g_new))
        threshold = c * (phi ** (st.t_rel + 1).astype(q.A.dtype)) * st.dtilde_I
        reject = jnp.logical_or(~jnp.isfinite(dt_new), dt_new > threshold)
        reject = jnp.logical_and(reject, st.m < m_max)

        def do_reject(_):
            m2 = jnp.minimum(st.m * 2, m_max)
            chol2 = _masked_factorize(q, S, m2)
            dt_I = 0.5 * jnp.sum(g * _chol_solve(chol2, g))
            g00 = q.grad(jnp.zeros_like(st.x))
            ref2 = 0.5 * jnp.sum(g00 * _chol_solve(chol2, g00))
            return (
                PaddedState(
                    x=st.x, m=m2, t_rel=jnp.asarray(0, jnp.int32),
                    dtilde_I=dt_I, dtilde=dt_I, chol=chol2, iters=st.iters,
                    doublings=st.doublings + 1,
                ),
                ref2,
            )

        def do_accept(_):
            return (
                PaddedState(
                    x=x_new, m=st.m, t_rel=st.t_rel + 1, dtilde_I=st.dtilde_I,
                    dtilde=dt_new, chol=st.chol, iters=st.iters + 1,
                    doublings=st.doublings,
                ),
                dt_ref,
            )

        return jax.lax.cond(reject, do_reject, do_accept, None)

    st, _ = jax.lax.while_loop(cond, body, (init, dt_ref))
    stats = {"m_final": st.m, "iters": st.iters, "doublings": st.doublings,
             "dtilde": st.dtilde}
    return st.x, stats
