"""Factorizations of the sketched Hessian H_S = (SA)ᵀ(SA) + ν²Λ (paper §4.1.1).

Two regimes, chosen exactly as in the paper:

* m ≥ d  (primal): form H_S ∈ R^{d×d}, Cholesky in O(d³); solves O(d²).
* m < d  (dual / Woodbury): form W_S = SAΛ⁻¹(SA)ᵀ + ν²I_m ∈ R^{m×m},
  Cholesky in O(m³); solves O(md) via
      v = Λ⁻¹/ν² · (I_d − (SA)ᵀ W_S⁻¹ SA Λ⁻¹) z .

The factorization object is a pytree so it can be closed over / donated in
jitted solver loops.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, cho_solve


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SketchedPrecond:
    """Cached factorization of H_S; solves  H_S v = z  in O(min(m,d)·d)."""

    mode: str               # "primal" | "dual"
    chol: jnp.ndarray       # (d,d) or (m,m) lower Cholesky factor
    SA: jnp.ndarray | None  # (m,d), kept only in dual mode
    nu2: jnp.ndarray        # scalar ν²
    lam_diag: jnp.ndarray   # (d,) diagonal of Λ

    def tree_flatten(self):
        return (self.chol, self.SA, self.nu2, self.lam_diag), (self.mode,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        chol, SA, nu2, lam = children
        return cls(mode=aux[0], chol=chol, SA=SA, nu2=nu2, lam_diag=lam)

    def solve(self, z: jnp.ndarray) -> jnp.ndarray:
        """Solve H_S v = z. Supports vector (d,) or matrix (d,c) RHS."""
        squeeze = z.ndim == 1
        if squeeze:
            z = z[:, None]
        if self.mode == "primal":
            v = cho_solve((self.chol, True), z)
        else:
            SA, nu2 = self.SA, self.nu2
            lam_inv = 1.0 / self.lam_diag
            zi = lam_inv[:, None] * z                      # Λ⁻¹ z
            w = cho_solve((self.chol, True), SA @ zi)      # W_S⁻¹ SA Λ⁻¹ z
            v = (zi - lam_inv[:, None] * (SA.T @ w)) / nu2
        return v[:, 0] if squeeze else v


def factorize(
    SA: jnp.ndarray,
    nu: float | jnp.ndarray,
    lam_diag: jnp.ndarray,
    *,
    jitter: float = 0.0,
) -> SketchedPrecond:
    """Factorize H_S given the sketched matrix SA ∈ R^{m×d}."""
    m, d = SA.shape
    nu2 = jnp.asarray(nu, SA.dtype) ** 2
    if m >= d:
        H_S = SA.T @ SA + jnp.diag(nu2 * lam_diag)
        if jitter:
            H_S = H_S + jitter * jnp.eye(d, dtype=SA.dtype)
        chol, _ = cho_factor(H_S, lower=True)
        return SketchedPrecond(
            mode="primal", chol=chol, SA=None, nu2=nu2, lam_diag=lam_diag
        )
    lam_inv = 1.0 / lam_diag
    W_S = (SA * lam_inv[None, :]) @ SA.T + nu2 * jnp.eye(m, dtype=SA.dtype)
    if jitter:
        W_S = W_S + jitter * jnp.eye(m, dtype=SA.dtype)
    chol, _ = cho_factor(W_S, lower=True)
    return SketchedPrecond(
        mode="dual", chol=chol, SA=SA, nu2=nu2, lam_diag=lam_diag
    )


def factorization_cost_flops(m: int, n: int, d: int) -> float:
    """Flops to form + factorize H_S (paper §4.1.1), excluding the sketch."""
    if m >= d:
        return 2.0 * m * d * d + d**3 / 3.0
    return 2.0 * m * m * d + m**3 / 3.0
