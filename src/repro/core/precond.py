"""Factorizations of the sketched Hessian H_S = (SA)ᵀ(SA) + ν²Λ (paper §4.1.1).

Two regimes, chosen exactly as in the paper:

* m ≥ d  (primal): form H_S ∈ R^{d×d}, Cholesky in O(d³); solves O(d²).
* m < d  (dual / Woodbury): form W_S = SAΛ⁻¹(SA)ᵀ + ν²I_m ∈ R^{m×m},
  Cholesky in O(m³); solves O(md) via
      v = Λ⁻¹/ν² · (I_d − (SA)ᵀ W_S⁻¹ SA Λ⁻¹) z .

Batch polymorphism (DESIGN.md §6): ``factorize`` accepts SA with a leading
problem axis (B, m, d) — the factorization and ``solve`` batch over it —
and ``factorize_shared`` covers the shared-sketch λ-batch, where one SA is
factorized against B different (ν, Λ) regularizers with the Gram matrix
(SAᵀSA, resp. SAΛ⁻¹SAᵀ) formed once.

``shifted_ladder_inverses`` generalizes the same shift-at-factorization
idea to the adaptive engine's doubling ladder (DESIGN.md §13): the
(L, B, d, d) level Grams (SA)ᵀ(SA) are λ-free — ν²Λ enters only here, as a
diagonal shift added immediately before the flattened batched Cholesky —
so ONE one-touch sketch pass serves every λ point of a regularization
path; only this O(L·B·d³) factorization is repeated per λ.

The factorization object is a pytree so it can be closed over / donated in
jitted solver loops.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax.scipy.linalg import cho_factor, solve_triangular


def _chol_solve(chol: jnp.ndarray, z: jnp.ndarray) -> jnp.ndarray:
    """Lower-Cholesky solve; batches over leading axes."""
    y = solve_triangular(chol, z, lower=True)
    return solve_triangular(jnp.swapaxes(chol, -1, -2), y, lower=False)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class SketchedPrecond:
    """Cached factorization of H_S; solves  H_S v = z  in O(min(m,d)·d)."""

    mode: str               # "primal" | "dual"
    chol: jnp.ndarray       # (d,d) or (m,m) lower Cholesky; (B,·,·) batched
    SA: jnp.ndarray | None  # (m,d) or (B,m,d), kept only in dual mode
    nu2: jnp.ndarray        # scalar ν²; (B,) batched
    lam_diag: jnp.ndarray   # (d,) diagonal of Λ; (B,d) batched
    batched: bool = False   # static: leading problem axis on chol/ν²/Λ

    def tree_flatten(self):
        return (self.chol, self.SA, self.nu2, self.lam_diag), (
            self.mode, self.batched)

    @classmethod
    def tree_unflatten(cls, aux, children):
        chol, SA, nu2, lam = children
        return cls(mode=aux[0], chol=chol, SA=SA, nu2=nu2, lam_diag=lam,
                   batched=aux[1])

    def solve(self, z: jnp.ndarray) -> jnp.ndarray:
        """Solve H_S v = z. Supports vector (d,) or matrix (d,c) RHS; with
        ``batched`` z carries the problem axis: (B, d)."""
        if self.batched:
            return self._solve_batched(z)
        squeeze = z.ndim == 1
        if squeeze:
            z = z[:, None]
        if self.mode == "primal":
            v = _chol_solve(self.chol, z)
        else:
            SA, nu2 = self.SA, self.nu2
            lam_inv = 1.0 / self.lam_diag
            zi = lam_inv[:, None] * z                      # Λ⁻¹ z
            w = _chol_solve(self.chol, SA @ zi)            # W_S⁻¹ SA Λ⁻¹ z
            v = (zi - lam_inv[:, None] * (SA.T @ w)) / nu2
        return v[:, 0] if squeeze else v

    def _solve_batched(self, z: jnp.ndarray) -> jnp.ndarray:
        if self.mode == "primal":
            return _chol_solve(self.chol, z[..., None])[..., 0]
        SA = self.SA
        lam_inv = 1.0 / self.lam_diag                      # (B, d)
        zi = lam_inv * z                                   # Λ⁻¹ z, (B, d)
        if SA.ndim == 2:                                   # shared sketch
            SAzi = jnp.einsum("md,bd->bm", SA, zi)
            w = _chol_solve(self.chol, SAzi[..., None])[..., 0]
            back = jnp.einsum("md,bm->bd", SA, w)
        else:
            SAzi = jnp.einsum("bmd,bd->bm", SA, zi)
            w = _chol_solve(self.chol, SAzi[..., None])[..., 0]
            back = jnp.einsum("bmd,bm->bd", SA, w)
        return (zi - lam_inv * back) / self.nu2[:, None]


def _diag_embed(x: jnp.ndarray) -> jnp.ndarray:
    return jax.vmap(jnp.diag)(x)


def factorize(
    SA: jnp.ndarray,
    nu: float | jnp.ndarray,
    lam_diag: jnp.ndarray,
    *,
    jitter: float = 0.0,
) -> SketchedPrecond:
    """Factorize H_S given the sketched matrix SA ∈ R^{m×d}, or a batch of
    sketched matrices SA ∈ R^{B×m×d} (ν, Λ broadcast or per-problem)."""
    if SA.ndim == 3:
        return _factorize_batched(SA, nu, lam_diag, jitter=jitter)
    m, d = SA.shape
    nu2 = jnp.asarray(nu, SA.dtype) ** 2
    if m >= d:
        H_S = SA.T @ SA + jnp.diag(nu2 * lam_diag)
        if jitter:
            H_S = H_S + jitter * jnp.eye(d, dtype=SA.dtype)
        chol, _ = cho_factor(H_S, lower=True)
        return SketchedPrecond(
            mode="primal", chol=chol, SA=None, nu2=nu2, lam_diag=lam_diag
        )
    lam_inv = 1.0 / lam_diag
    W_S = (SA * lam_inv[None, :]) @ SA.T + nu2 * jnp.eye(m, dtype=SA.dtype)
    if jitter:
        W_S = W_S + jitter * jnp.eye(m, dtype=SA.dtype)
    chol, _ = cho_factor(W_S, lower=True)
    return SketchedPrecond(
        mode="dual", chol=chol, SA=SA, nu2=nu2, lam_diag=lam_diag
    )


def _factorize_batched(SA, nu, lam_diag, *, jitter: float = 0.0
                       ) -> SketchedPrecond:
    B, m, d = SA.shape
    nu2 = jnp.broadcast_to(
        jnp.atleast_1d(jnp.asarray(nu, SA.dtype)) ** 2, (B,))
    lam_diag = jnp.broadcast_to(jnp.asarray(lam_diag, SA.dtype), (B, d))
    if m >= d:
        H_S = jnp.einsum("bmd,bme->bde", SA, SA) + _diag_embed(
            nu2[:, None] * lam_diag)
        if jitter:
            H_S = H_S + jitter * jnp.eye(d, dtype=SA.dtype)
        chol = jnp.linalg.cholesky(H_S)
        return SketchedPrecond(mode="primal", chol=chol, SA=None, nu2=nu2,
                               lam_diag=lam_diag, batched=True)
    lam_inv = 1.0 / lam_diag
    W_S = jnp.einsum("bmd,bnd->bmn", SA * lam_inv[:, None, :], SA) + (
        nu2[:, None, None] * jnp.eye(m, dtype=SA.dtype))
    if jitter:
        W_S = W_S + jitter * jnp.eye(m, dtype=SA.dtype)
    chol = jnp.linalg.cholesky(W_S)
    return SketchedPrecond(mode="dual", chol=chol, SA=SA, nu2=nu2,
                           lam_diag=lam_diag, batched=True)


def factorize_shared(
    SA: jnp.ndarray,
    nu: jnp.ndarray,
    lam_diag: jnp.ndarray,
    *,
    jitter: float = 0.0,
) -> SketchedPrecond:
    """λ-batch fast path: ONE sketched matrix SA (m, d) factorized against a
    batch of regularizers ν (B,), Λ (B, d) — e.g. a regularization path or
    per-tenant λ heads over shared data.

    The O(md²) Gram product SAᵀSA (primal) is computed once; only the B
    diagonal additions and Cholesky factorizations are batched. In the dual
    (m < d) regime the Λ-weighted Gram SAΛ⁻¹SAᵀ is shared only when Λ is
    shared across the batch; per-problem Λ falls back to a batched Gram."""
    m, d = SA.shape
    nu2 = jnp.atleast_1d(jnp.asarray(nu, SA.dtype)) ** 2
    B = nu2.shape[0]
    lam_shared = jnp.asarray(lam_diag, SA.dtype).ndim == 1
    lam_diag = jnp.broadcast_to(jnp.asarray(lam_diag, SA.dtype), (B, d))
    if m >= d:
        G = SA.T @ SA                                        # once, shared
        H_S = G[None, :, :] + _diag_embed(nu2[:, None] * lam_diag)
        if jitter:
            H_S = H_S + jitter * jnp.eye(d, dtype=SA.dtype)
        chol = jnp.linalg.cholesky(H_S)
        return SketchedPrecond(mode="primal", chol=chol, SA=None, nu2=nu2,
                               lam_diag=lam_diag, batched=True)
    if lam_shared:
        K = (SA * (1.0 / lam_diag[0])[None, :]) @ SA.T       # once, shared
        W_S = K[None, :, :] + nu2[:, None, None] * jnp.eye(m, dtype=SA.dtype)
    else:
        W_S = jnp.einsum("md,bd,nd->bmn", SA, 1.0 / lam_diag, SA) + (
            nu2[:, None, None] * jnp.eye(m, dtype=SA.dtype))
    if jitter:
        W_S = W_S + jitter * jnp.eye(m, dtype=SA.dtype)
    chol = jnp.linalg.cholesky(W_S)
    return SketchedPrecond(mode="dual", chol=chol, SA=SA, nu2=nu2,
                           lam_diag=lam_diag, batched=True)


def shifted_ladder_inverses(
    grams: jnp.ndarray,
    nu: jnp.ndarray,
    lam_diag: jnp.ndarray,
) -> jnp.ndarray:
    """Per-λ shifted factorization of a λ-FREE ladder of level Grams.

    ``grams`` is the (L, B, d, d) stack of unshifted sketched Grams
    (SA)ᵀ(SA) at every doubling-ladder level — the output of one one-touch
    sketch pass, independent of the regularizer. The ν²Λ shift is applied
    HERE, so a regularization path factorizes the same ladder once per λ
    point (O(L·B·d³) each) while paying the O(B·m_max·n·d) sketch pass
    exactly once for the whole grid (DESIGN.md §13).

    Returns the (L, B, d, d) explicit inverses (G_l + ν²Λ)⁻¹ via one
    flattened batched Cholesky + two triangular solves — with the inverses
    precomputed, a doubling inside the solve loop is a pure gather and the
    per-iteration preconditioner application one fused batched matvec.
    The forward error of an explicit inverse is the same O(ε·κ) as
    triangular solves, which a *preconditioner* tolerates."""
    L, B, d, _ = grams.shape
    reg = (nu**2)[:, None] * lam_diag                        # (B, d)
    HS = grams + jax.vmap(jnp.diag)(reg)[None, :, :, :]
    HS = HS.reshape(L * B, d, d)
    chol = jnp.linalg.cholesky(HS)
    eye = jnp.broadcast_to(jnp.eye(d, dtype=HS.dtype), HS.shape)
    y = solve_triangular(chol, eye, lower=True)
    pinv = solve_triangular(jnp.swapaxes(chol, -1, -2), y, lower=False)
    return pinv.reshape(L, B, d, d)


def factorization_cost_flops(m: int, n: int, d: int) -> float:
    """Flops to form + factorize H_S (paper §4.1.1), excluding the sketch."""
    if m >= d:
        return 2.0 * m * d * d + d**3 / 3.0
    return 2.0 * m * m * d + m**3 / 3.0
