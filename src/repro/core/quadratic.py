"""Problem container for  min_x ½⟨x, Hx⟩ − bᵀx,  H = AᵀA + ν²Λ  (paper (1.1)).

``Quadratic`` is matrix-free: it exposes Hv, ∇f, f, and the sketch of A.
It supports matrix right-hand sides B ∈ R^{d×c} (multi-class heads — the
paper's experiments use one-hot label matrices).

A distributed (row-sharded) variant lives in ``repro.core.distributed``; this
module is the single-device semantics both share.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quadratic:
    A: jnp.ndarray          # (n, d) data matrix
    b: jnp.ndarray          # (d,) or (d, c) linear term (= Aᵀy for LS)
    nu: jnp.ndarray         # scalar regularization ν
    lam_diag: jnp.ndarray   # (d,) diagonal of Λ ⪰ I

    def tree_flatten(self):
        return (self.A, self.b, self.nu, self.lam_diag), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    # -- dimensions --------------------------------------------------------
    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]

    # -- operator ----------------------------------------------------------
    def hvp(self, v: jnp.ndarray) -> jnp.ndarray:
        """H v = AᵀA v + ν²Λ v  in O(nd) (never forms H)."""
        lam = self.lam_diag
        if v.ndim == 1:
            return self.A.T @ (self.A @ v) + (self.nu**2) * lam * v
        return self.A.T @ (self.A @ v) + (self.nu**2) * lam[:, None] * v

    def grad(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.hvp(x) - self.b

    def value(self, x: jnp.ndarray) -> jnp.ndarray:
        return 0.5 * jnp.sum(x * self.hvp(x)) - jnp.sum(self.b * x)

    def error(self, x: jnp.ndarray, x_star: jnp.ndarray) -> jnp.ndarray:
        """δ_x = ½‖x − x*‖²_H (summed over columns for matrix RHS)."""
        dx = x - x_star
        return 0.5 * jnp.sum(dx * self.hvp(dx))


def from_least_squares(A, y, nu, lam_diag=None) -> Quadratic:
    """Ridge regression  min ½‖Ax − y‖² + ν²/2 ‖Λ^{1/2}x‖²  as (1.1)."""
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    if lam_diag is None:
        lam_diag = jnp.ones((A.shape[1],), A.dtype)
    return Quadratic(A=A, b=A.T @ y, nu=jnp.asarray(nu, A.dtype), lam_diag=lam_diag)


def direct_solve(q: Quadratic) -> jnp.ndarray:
    """Baseline: dense Cholesky factor-and-solve, O(nd²+d³) (paper baseline)."""
    H = q.A.T @ q.A + jnp.diag((q.nu**2) * q.lam_diag)
    chol, _ = jax.scipy.linalg.cho_factor(H, lower=True)
    return jax.scipy.linalg.cho_solve((chol, True), q.b)
