"""Problem container for  min_x ½⟨x, Hx⟩ − bᵀx,  H = AᵀW A + ν²Λ  (paper (1.1)).

``Quadratic`` is matrix-free: it exposes Hv, ∇f, f, and the sketch of A.
It supports matrix right-hand sides B ∈ R^{d×c} (multi-class heads — the
paper's experiments use one-hot label matrices).

Row weights (DESIGN.md §8): an optional ``row_weights`` w ≥ 0 turns the
Gram into AᵀWA with W = diag(w) — the Hessian of every regularized GLM's
Newton subproblem (AᵀW(x)A + ν²Λ) Δ = −∇F. The container stays matrix-free
about it: ``hvp`` computes Aᵀ(w ⊙ (Av)) so the weighted matrix W^{1/2}A is
NEVER materialized; the sketch providers (``core.level_grams``) fuse w^{1/2}
into their one streaming pass over A the same way. w is (n,) for single
problems and (B, n) — per problem, even with shared A — when batched.

Batch polymorphism (DESIGN.md §6): every op also accepts a *leading problem
axis*. A batched ``Quadratic`` (``batched=True``) holds B independent
problems and comes in two layouts:

* per-problem data:  A (B, n, d), b (B, d), ν (B,), Λ (B, d);
* shared-A λ-batch:  A (n, d) shared, b (B, d), ν (B,), Λ (B, d) — the
  layout of hyperparameter sweeps / per-tenant heads over one dataset,
  where the Gram matrix AᵀA is computed ONCE and reused across the batch.

``batched`` is static pytree metadata, so jitted solvers specialize on it
without retracing per batch size. Scalar reductions (value, error, δ̃)
return a (B,) vector in batched mode.

A distributed (row-sharded) variant lives in ``repro.core.distributed``; this
module is the single-device semantics both share.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


def pdot(a: jnp.ndarray, b: jnp.ndarray, batched: bool) -> jnp.ndarray:
    """⟨a, b⟩ summed over all axes — except the leading problem axis when
    ``batched`` (returns (B,))."""
    if batched:
        return jnp.sum(a * b, axis=tuple(range(1, a.ndim)))
    return jnp.sum(a * b)


def pscale(c: jnp.ndarray, batched: bool) -> jnp.ndarray:
    """Broadcast a per-problem scalar (B,) against (B, d) state arrays."""
    return c[..., None] if batched else c


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Quadratic:
    A: jnp.ndarray          # (n, d) data matrix; (B, n, d) or shared (n, d)
    b: jnp.ndarray          # (d,) or (d, c); (B, d) when batched
    nu: jnp.ndarray         # scalar regularization ν; (B,) when batched
    lam_diag: jnp.ndarray   # (d,) diagonal of Λ ⪰ I; (B, d) when batched
    batched: bool = False   # static: leading problem axis on b/ν/Λ (and A
                            # unless shared)
    row_weights: jnp.ndarray | None = None  # W = diag(w): (n,); (B, n) when
                            # batched (per problem even with shared A)

    def tree_flatten(self):
        return (self.A, self.b, self.nu, self.lam_diag,
                self.row_weights), (self.batched,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children[:4], batched=aux[0], row_weights=children[4])

    # -- dimensions --------------------------------------------------------
    @property
    def shared_A(self) -> bool:
        return self.batched and self.A.ndim == 2

    @property
    def n(self) -> int:
        return self.A.shape[-2]

    @property
    def d(self) -> int:
        return self.A.shape[-1]

    @property
    def batch(self) -> int:
        if not self.batched:
            raise ValueError("not a batched problem")
        return self.b.shape[0]

    # -- operator ----------------------------------------------------------
    def _reg(self, v: jnp.ndarray) -> jnp.ndarray:
        """ν²Λ v with the layout-appropriate broadcast."""
        if self.batched:
            return (self.nu**2)[:, None] * self.lam_diag * v
        lam = self.lam_diag
        if v.ndim == 1:
            return (self.nu**2) * lam * v
        return (self.nu**2) * lam[:, None] * v

    def hvp(self, v: jnp.ndarray) -> jnp.ndarray:
        """H v = AᵀWA v + ν²Λ v  in O(nd) per problem (never forms H or
        W^{1/2}A: the weight lands on the (·, n) intermediate Av)."""
        w = self.row_weights
        if self.batched:
            if self.shared_A:
                Av = v @ self.A.T                      # (B, n)
                if w is not None:
                    Av = w * Av
                AtAv = Av @ self.A                     # (B, d)
            else:
                Av = jnp.einsum("bnd,bd->bn", self.A, v)
                if w is not None:
                    Av = w * Av
                AtAv = jnp.einsum("bnd,bn->bd", self.A, Av)
            return AtAv + self._reg(v)
        Av = self.A @ v
        if w is not None:
            Av = (w[:, None] if Av.ndim == 2 else w) * Av
        return self.A.T @ Av + self._reg(v)

    def grad(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.hvp(x) - self.b

    def value(self, x: jnp.ndarray) -> jnp.ndarray:
        return 0.5 * pdot(x, self.hvp(x), self.batched) - pdot(
            self.b, x, self.batched
        )

    def error(self, x: jnp.ndarray, x_star: jnp.ndarray) -> jnp.ndarray:
        """δ_x = ½‖x − x*‖²_H (summed over columns for matrix RHS; per
        problem for batched)."""
        dx = x - x_star
        return 0.5 * pdot(dx, self.hvp(dx), self.batched)

    # -- batch utilities ---------------------------------------------------
    def problem(self, i: int) -> "Quadratic":
        """Extract problem i of a batched Quadratic as a single problem."""
        if not self.batched:
            raise ValueError("not a batched problem")
        A = self.A if self.shared_A else self.A[i]
        w = None if self.row_weights is None else self.row_weights[i]
        return Quadratic(A=A, b=self.b[i], nu=self.nu[i],
                         lam_diag=self.lam_diag[i], row_weights=w)

    def with_row_weights(self, w: jnp.ndarray | None) -> "Quadratic":
        """Same problem under the weighted Gram AᵀWA (W = diag(w)).

        ``w`` is (n,) single / (B, n) batched — per problem even when A is
        shared, which is the Newton-subproblem layout (weights depend on
        the iterate)."""
        if w is not None:
            w = jnp.asarray(w, self.A.dtype)
            want = (self.batch, self.n) if self.batched else (self.n,)
            if w.shape != want:
                raise ValueError(
                    f"row_weights shape {w.shape} != expected {want}")
        return dataclasses.replace(self, row_weights=w)


def _as_batched_reg(nu, lam_diag, B: int, d: int, dtype):
    """Materialize ν as (B,) and Λ as (B, d) so batched ops are uniform."""
    nu = jnp.broadcast_to(jnp.atleast_1d(jnp.asarray(nu, dtype)), (B,))
    if lam_diag is None:
        lam_diag = jnp.ones((d,), dtype)
    lam_diag = jnp.broadcast_to(jnp.asarray(lam_diag, dtype), (B, d))
    return nu, lam_diag


def from_least_squares(A, y, nu, lam_diag=None) -> Quadratic:
    """Ridge regression  min ½‖Ax − y‖² + ν²/2 ‖Λ^{1/2}x‖²  as (1.1)."""
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    if lam_diag is None:
        lam_diag = jnp.ones((A.shape[1],), A.dtype)
    return Quadratic(A=A, b=A.T @ y, nu=jnp.asarray(nu, A.dtype), lam_diag=lam_diag)


def from_least_squares_batch(A, Y, nu, lam_diag=None) -> Quadratic:
    """Batched ridge:  A (B, n, d) per-problem or (n, d) shared; Y (B, n);
    ν scalar or (B,); Λ (d,) or (B, d)."""
    A = jnp.asarray(A)
    Y = jnp.asarray(Y)
    B, d = Y.shape[0], A.shape[-1]
    if A.ndim == 2:
        b = Y @ A                                   # (B, d), shared Gram path
    else:
        b = jnp.einsum("bnd,bn->bd", A, Y)
    nu, lam_diag = _as_batched_reg(nu, lam_diag, B, d, A.dtype)
    return Quadratic(A=A, b=b, nu=nu, lam_diag=lam_diag, batched=True)


def lambda_sweep(A, y, nus, lam_diag=None) -> Quadratic:
    """Shared-A regularization-path batch: one (A, y), B values of ν.

    The returned problem has A shared, so Gram-forming consumers
    (``direct_solve``, ``precond.factorize_shared``) pay the O(nd²) once."""
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    nus = jnp.asarray(nus, A.dtype)
    b1 = A.T @ y
    b = jnp.broadcast_to(b1[None, :], (nus.shape[0], A.shape[1]))
    nu, lam_diag = _as_batched_reg(nus, lam_diag, nus.shape[0], A.shape[1],
                                   A.dtype)
    return Quadratic(A=A, b=b, nu=nu, lam_diag=lam_diag, batched=True)


def stack_quadratics(qs: list[Quadratic]) -> Quadratic:
    """Stack same-shape single problems along a new leading problem axis.
    Row weights stack too (all problems weighted or none — a mix has no
    faithful batched representation and must not silently drop weights)."""
    if any(q.batched for q in qs):
        raise ValueError("stack_quadratics takes single problems")
    n_weighted = sum(q.row_weights is not None for q in qs)
    if n_weighted not in (0, len(qs)):
        raise ValueError(
            f"cannot stack {n_weighted} weighted with "
            f"{len(qs) - n_weighted} unweighted problems")
    A = jnp.stack([q.A for q in qs])
    b = jnp.stack([q.b for q in qs])
    nu = jnp.stack([jnp.asarray(q.nu) for q in qs])
    lam = jnp.stack([q.lam_diag for q in qs])
    w = (jnp.stack([q.row_weights for q in qs]) if n_weighted else None)
    return Quadratic(A=A, b=b, nu=nu, lam_diag=lam, batched=True,
                     row_weights=w)


def weighted_gram(A: jnp.ndarray, w: jnp.ndarray, *,
                  chunk: int = 1024) -> jnp.ndarray:
    """AᵀWA as (B, d, d) without materializing W^{1/2}A: a ``lax.scan``
    over n-chunks whose only weighted intermediate is the (B, chunk, d)
    tile — never an (n, d)-sized weighted copy of A (the streaming
    guarantee the engine's weighted ``gram_hvp`` relies on).

    A is (B, n, d) per-problem or (n, d) shared; w is (B, n)."""
    shared = A.ndim == 2
    n, d = A.shape[-2], A.shape[-1]
    B = w.shape[0]
    chunk = min(chunk, n)
    pad = (-n) % chunk
    if pad:
        # zero rows carry zero weight: they add exact zeros to the Gram
        A = jnp.pad(A, ((0, pad), (0, 0)) if shared
                    else ((0, 0), (0, pad), (0, 0)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    steps = (n + pad) // chunk

    def step(acc, c_idx):
        r0 = c_idx * chunk
        a_c = jax.lax.dynamic_slice_in_dim(A, r0, chunk, axis=A.ndim - 2)
        w_c = jax.lax.dynamic_slice_in_dim(w, r0, chunk, axis=1)
        if shared:
            g = jnp.einsum("bc,cd,ce->bde", w_c, a_c, a_c)
        else:
            g = jnp.einsum("bc,bcd,bce->bde", w_c, a_c, a_c)
        return acc + g, None

    acc0 = jnp.zeros((B, d, d), A.dtype)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(steps))
    return acc


def direct_solve(q: Quadratic) -> jnp.ndarray:
    """Baseline: dense Cholesky factor-and-solve, O(nd²+d³) (paper baseline).

    Batched problems get a batched Cholesky; with shared A the Gram matrix
    is formed once and only the ν²Λ diagonal varies across the batch.
    Weighted problems form AᵀWA (this is the dense oracle — materializing
    the weighted matrix is fine here)."""
    w = q.row_weights
    if q.batched:
        from .precond import _chol_solve

        if q.shared_A and w is None:
            G = q.A.T @ q.A                                    # (d, d) once
            H = G[None, :, :] + jax.vmap(jnp.diag)((q.nu**2)[:, None]
                                                   * q.lam_diag)
        else:
            if q.shared_A:                   # per-problem W breaks sharing
                G = jnp.einsum("bn,nd,ne->bde", w, q.A, q.A)
            elif w is None:
                G = jnp.einsum("bnd,bne->bde", q.A, q.A)
            else:
                G = jnp.einsum("bn,bnd,bne->bde", w, q.A, q.A)
            H = G + jax.vmap(jnp.diag)((q.nu**2)[:, None] * q.lam_diag)
        chol = jnp.linalg.cholesky(H)
        return _chol_solve(chol, q.b[..., None])[..., 0]
    Aw = q.A if w is None else q.A * w[:, None]
    H = Aw.T @ q.A + jnp.diag((q.nu**2) * q.lam_diag)
    chol, _ = jax.scipy.linalg.cho_factor(H, lower=True)
    return jax.scipy.linalg.cho_solve((chol, True), q.b)
