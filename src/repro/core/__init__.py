"""Core library: the paper's adaptive sketching-based solvers.

Layout:
  sketches.py       Gaussian / SRHT / SJLT embeddings (+ FWHT reference)
  precond.py        H_S factorizations (Cholesky primal / Woodbury dual)
  quadratic.py      problem container (matrix-free H·v, ∇f)
  solvers.py        IHS / PCG / Polyak-IHS / plain CG
  adaptive.py       Algorithm 4.1 / 4.2 (host-orchestrated doubling)
  adaptive_padded.py  beyond-paper single-XLA-program masked adaptivity
  effective_dim.py  d_e and critical sketch sizes (Table 1 / Thm 5.1)
  distributed.py    row-sharded A: block sketches + GSPMD solver steps
"""

from .adaptive import AdaptiveConfig, AdaptiveResult, adaptive_solve, k_max
from .effective_dim import (
    effective_dimension,
    effective_dimension_exact,
    exp_decay_singular_values,
    m_delta_gaussian,
    m_delta_sjlt,
    m_delta_srht,
)
from .precond import SketchedPrecond, factorize
from .quadratic import Quadratic, direct_solve, from_least_squares
from .sketches import Sketch, fwht, make_sketch
from .solvers import cg_solve, newton_solve, run_fixed

__all__ = [
    "AdaptiveConfig",
    "AdaptiveResult",
    "adaptive_solve",
    "k_max",
    "effective_dimension",
    "effective_dimension_exact",
    "exp_decay_singular_values",
    "m_delta_gaussian",
    "m_delta_sjlt",
    "m_delta_srht",
    "SketchedPrecond",
    "factorize",
    "Quadratic",
    "direct_solve",
    "from_least_squares",
    "Sketch",
    "fwht",
    "make_sketch",
    "cg_solve",
    "newton_solve",
    "run_fixed",
]
