"""Core library: the paper's adaptive sketching-based solvers.

Layout:
  sketches.py       Gaussian / SRHT / SJLT embeddings (+ FWHT reference)
  precond.py        H_S factorizations (Cholesky primal / Woodbury dual)
  quadratic.py      problem container (matrix-free H·v, ∇f)
  solvers.py        IHS / PCG / Polyak-IHS / plain CG
  adaptive.py       Algorithm 4.1 / 4.2 (host-orchestrated doubling)
  adaptive_padded.py  beyond-paper single-XLA-program masked adaptivity,
                    batch-polymorphic multi-problem engine (DESIGN.md §6)
  effective_dim.py  d_e and critical sketch sizes (Table 1 / Thm 5.1)
  distributed.py    row-sharded A: block sketches + GSPMD solver steps
  objectives.py     regularized GLM losses (logistic/poisson/huber/quadratic)
  newton.py         adaptive sketched-Newton driver over the padded engine
  status.py         per-problem SolveStatus failure lattice (DESIGN.md §9)
  robust.py         retry-with-redrawn-sketch + direct-solve fallback driver,
                    segmented/preemptible solve driver (DESIGN.md §11)

Every core op accepts an optional leading problem axis (batched
``Quadratic``) — see quadratic.py and DESIGN.md §6. Weighted Grams AᵀWA
(GLM Newton systems) ride through ``Quadratic.row_weights`` — DESIGN.md §8.
"""

from .adaptive import AdaptiveConfig, AdaptiveResult, adaptive_solve, k_max
from .adaptive_padded import (
    PaddedPrecompute,
    PaddedState,
    finalize_padded_solve,
    padded_adaptive_solve,
    padded_adaptive_solve_batched,
    padded_path_solve_batched,
    padded_solve_segment,
    padded_trip_cap,
    prepare_padded_solve,
    prepare_path_ladder,
    reprecondition_padded,
)
from .effective_dim import (
    effective_dimension,
    effective_dimension_exact,
    effective_dimension_weighted_exact,
    exp_decay_singular_values,
    m_delta_gaussian,
    m_delta_sjlt,
    m_delta_srht,
)
from .newton import (
    adaptive_newton_solve,
    adaptive_newton_solve_batched,
    irls_reference,
    newton_cg_reference,
)
from .objectives import GLM_FAMILIES, GLMObjective, get_objective
from .precond import (
    SketchedPrecond,
    factorize,
    factorize_shared,
    shifted_ladder_inverses,
)
from .quadratic import (
    Quadratic,
    direct_solve,
    from_least_squares,
    from_least_squares_batch,
    lambda_sweep,
    stack_quadratics,
    weighted_gram,
)
from .robust import (
    PreemptedError,
    robust_padded_solve_batched,
    robust_path_solve_batched,
    segmented_padded_solve_batched,
)
from .sketches import Sketch, fwht, make_sketch
from .solvers import cg_solve, newton_solve, run_fixed
from .status import (
    CONVERGED_STATUSES,
    ENGINE_FAILURES,
    SolveStatus,
    status_name,
)

__all__ = [
    "AdaptiveConfig",
    "AdaptiveResult",
    "adaptive_solve",
    "padded_adaptive_solve",
    "padded_adaptive_solve_batched",
    "PaddedState",
    "PaddedPrecompute",
    "prepare_padded_solve",
    "prepare_path_ladder",
    "padded_path_solve_batched",
    "padded_solve_segment",
    "finalize_padded_solve",
    "reprecondition_padded",
    "padded_trip_cap",
    "k_max",
    "effective_dimension",
    "effective_dimension_exact",
    "effective_dimension_weighted_exact",
    "exp_decay_singular_values",
    "m_delta_gaussian",
    "m_delta_sjlt",
    "m_delta_srht",
    "SketchedPrecond",
    "factorize",
    "factorize_shared",
    "shifted_ladder_inverses",
    "Quadratic",
    "direct_solve",
    "from_least_squares",
    "from_least_squares_batch",
    "lambda_sweep",
    "stack_quadratics",
    "weighted_gram",
    "GLM_FAMILIES",
    "GLMObjective",
    "get_objective",
    "adaptive_newton_solve",
    "adaptive_newton_solve_batched",
    "irls_reference",
    "newton_cg_reference",
    "Sketch",
    "fwht",
    "make_sketch",
    "cg_solve",
    "newton_solve",
    "run_fixed",
    "robust_padded_solve_batched",
    "robust_path_solve_batched",
    "segmented_padded_solve_batched",
    "PreemptedError",
    "SolveStatus",
    "ENGINE_FAILURES",
    "CONVERGED_STATUSES",
    "status_name",
]
