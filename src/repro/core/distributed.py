"""Distributed (row-sharded) quadratic problems and block sketches.

Layout: A ∈ R^{n×d} is row-sharded over the mesh's data axes (the layout
backbone activations already have under DP), x/b replicated. Then:

* H·v      = AᵀA v + ν²Λv  — local matmuls + one psum(d) over data axes.
* sketch   = S·A with *independent per-shard randomness* (block sketching):
             SA = Σ_k S_k A_k — local sketch + one psum(m×d). For the SRHT
             this is the block-SRHT (per-shard sign diagonal + FWHT, global
             row budget split across shards); embedding properties hold up
             to constants (DESIGN.md §5).
* factorization / iterations — replicated (m, d ≪ n).

Two execution paths, same math:

1. **GSPMD path** (production): jit the plain ``Quadratic`` ops with
   ``in_shardings`` placing A as P(data_axes, None); XLA inserts the
   collectives. Used by the dry-run and the large-scale configs.
2. **shard_map path** (explicit collectives): used where we want manual
   control of the reduction placement — the sketch+Gram hot path — and by
   the multi-device tests.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .precond import factorize
from .quadratic import Quadratic
from .sketches import make_sketch


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes used for data parallelism (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def shard_quadratic(q: Quadratic, mesh: Mesh) -> Quadratic:
    """Place A row-sharded over the data axes, everything else replicated."""
    da = data_axes(mesh)
    a_sh = NamedSharding(mesh, P(da, None))
    rep = NamedSharding(mesh, P())
    return Quadratic(
        A=jax.device_put(q.A, a_sh),
        b=jax.device_put(q.b, rep),
        nu=jax.device_put(q.nu, rep),
        lam_diag=jax.device_put(q.lam_diag, rep),
    )


# ---------------------------------------------------------------------------
# Explicit shard_map path for the sketch + factorize hot path
# ---------------------------------------------------------------------------

def block_sketch_gram(
    A: jnp.ndarray,
    key: jax.Array,
    kind: str,
    m: int,
    mesh: Mesh,
    *,
    s: int = 1,
):
    """Compute SA = Σ_k S_k A_k with per-shard randomness, under shard_map.

    Returns the replicated (m, d) sketched matrix. The per-shard sketch uses
    ``jax.random.fold_in(key, shard_index)`` so shards are independent, and
    the row budget m is kept global (each shard contributes to all m rows —
    this is summing sketches, not concatenating).
    """
    da = data_axes(mesh)
    n_shards = 1
    for a in da:
        n_shards *= mesh.shape[a]
    n = A.shape[0]
    if n % n_shards:
        raise ValueError(f"n={n} not divisible by {n_shards} data shards")

    def local_sketch(A_blk: jnp.ndarray) -> jnp.ndarray:
        idx = jax.lax.axis_index(da)
        k = jax.random.fold_in(key, idx)
        sk = make_sketch(kind, m, A_blk.shape[0], k, dtype=A_blk.dtype, s=s)
        partial_SA = sk.apply(A_blk) / jnp.sqrt(
            jnp.asarray(n_shards, A_blk.dtype)
        )
        return jax.lax.psum(partial_SA, axis_name=da)

    fn = jax.shard_map(
        local_sketch,
        mesh=mesh,
        in_specs=P(da, None),
        out_specs=P(),
        check_vma=False,
    )
    return fn(A)


def distributed_sketch_and_factorize(
    q: Quadratic, key: jax.Array, kind: str, m: int, mesh: Mesh, *, s: int = 1
):
    """Block sketch + replicated factorization of H_S."""
    SA = block_sketch_gram(q.A, key, kind, m, mesh, s=s)
    return factorize(SA, q.nu, q.lam_diag)


# ---------------------------------------------------------------------------
# GSPMD shardings (used by dryrun / launch): jit the plain Quadratic ops with
# these and XLA inserts the data-axis collectives.
# ---------------------------------------------------------------------------

def quadratic_shardings(mesh: Mesh) -> Quadratic:
    """Sharding pytree matching Quadratic: A row-sharded, rest replicated."""
    da = data_axes(mesh)
    return Quadratic(
        A=NamedSharding(mesh, P(da, None)),
        b=NamedSharding(mesh, P()),
        nu=NamedSharding(mesh, P()),
        lam_diag=NamedSharding(mesh, P()),
    )
