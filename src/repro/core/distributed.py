"""Distributed (row-sharded) quadratic problems and block sketches.

Layout: A ∈ R^{n×d} is row-sharded over the mesh's data axes (the layout
backbone activations already have under DP) — batched problems shard the
row axis of each problem's (B, n, d) block, shared-A batches shard the one
(n, d) matrix. x/b/ν/Λ are replicated. Then:

* H·v      = AᵀA v + ν²Λv  — local matmuls + one psum(d) over data axes
             (or collective-free in-loop when the Gram is precomputed).
* sketch   — block sketching with *independent per-shard randomness*
             (``fold_in(key, shard_index)``), in two equivalent-in-
             expectation constructions (DESIGN.md §5):

             - **summed** (``block_sketch_gram``): SA = Σ_k S_k A_k, one
               local sketch + one psum(m×d). Because each S_k is an
               independent zero-mean embedding with E[S_kᵀS_k] = I on its
               block, E[(SA)ᵀSA] = Σ_k A_kᵀA_k = AᵀA with NO rescale —
               cross terms vanish in expectation.
             - **concatenated** (``shard_level_grams``): S = blockdiag(S_k),
               so (SA)ᵀ(SA) = Σ_k (S_k A_k)ᵀ(S_k A_k) exactly — each shard
               runs its family's one-touch ladder pass locally and the
               (L, B, d, d) level Grams are combined by ONE psum. Again no
               rescale: per-shard Gaussian entries are already N(0, 1/m),
               and SJLT/SRHT blocks satisfy E[S_kᵀS_k] = I on their block.

* factorization / iterations — replicated (m, d ≪ n).

Two execution paths, same math:

1. **GSPMD path** (production): jit the solver with A placed
   P(data_axes, None); XLA inserts the collectives. The padded adaptive
   engine takes ``mesh=`` (``sharded_padded_solve``) and swaps only its
   precompute for the explicit one-touch pass below — the in-loop hvp's
   AᵀA·v reduction is the only per-iteration collective (and none at all
   when the Gram is precomputed, the serving default).
2. **shard_map path** (explicit collectives): manual control of the
   reduction placement for the sketch+Gram hot path — ``shard_level_grams``
   is what the engine's precompute calls under ``mesh=``.

The sharded level Grams are λ-free like their single-device counterparts
(``level_grams``), so a sharded regularization path pays the SAME one
psum of the (L, B, d, d) stack for the entire λ grid
(``adaptive_padded.prepare_path_ladder(..., mesh=)`` — DESIGN.md §13);
per-λ shifted factorizations happen on the replicated Grams with no
further collectives.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .level_grams import LevelGramProvider
from .precond import factorize
from .quadratic import Quadratic
from .sketches import make_sketch

# jax ≥ 0.6 exposes jax.shard_map(check_vma=...); 0.4.x/0.5.x only the
# experimental entry point with the older check_rep spelling.
if hasattr(jax, "shard_map"):
    _shard_map_fn, _CHECK_KW = jax.shard_map, "check_vma"
else:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map_fn

    _CHECK_KW = "check_rep"


def _smap(f, mesh: Mesh, in_specs, out_specs):
    """shard_map with replication checking off, on every supported jax."""
    return _shard_map_fn(f, mesh=mesh, in_specs=in_specs,
                         out_specs=out_specs, **{_CHECK_KW: False})


def data_axes(mesh: Mesh) -> tuple[str, ...]:
    """All mesh axes used for data parallelism (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def n_data_shards(mesh: Mesh) -> int:
    """Number of row shards = product of the data-axis sizes."""
    k = 1
    for a in data_axes(mesh):
        k *= mesh.shape[a]
    return k


def _a_row_spec(q: Quadratic, mesh: Mesh) -> P:
    """PartitionSpec sharding A's row axis over the data axes."""
    da = data_axes(mesh)
    if q.batched and not q.shared_A:
        return P(None, da, None)          # (B, n, d): shard axis 1
    return P(da, None)                    # (n, d): shard axis 0


def _w_row_spec(q: Quadratic, mesh: Mesh) -> P:
    """PartitionSpec for row_weights: the row axis shards with A's."""
    da = data_axes(mesh)
    if q.batched:
        return P(None, da)                # (B, n): shard axis 1
    return P(da)                          # (n,)


def shard_quadratic(q: Quadratic, mesh: Mesh) -> Quadratic:
    """Place A (and any row_weights) row-sharded over the data axes,
    everything else replicated.

    Works for single problems, per-problem batches (B, n, d) and shared-A
    batches alike; the ``batched`` flag is preserved."""
    a_sh = NamedSharding(mesh, _a_row_spec(q, mesh))
    rep = NamedSharding(mesh, P())
    w = q.row_weights
    if w is not None:
        w = jax.device_put(w, NamedSharding(mesh, _w_row_spec(q, mesh)))
    return Quadratic(
        A=jax.device_put(q.A, a_sh),
        b=jax.device_put(q.b, rep),
        nu=jax.device_put(q.nu, rep),
        lam_diag=jax.device_put(q.lam_diag, rep),
        batched=q.batched,
        row_weights=w,
    )


def _check_divisible(n: int, mesh: Mesh) -> int:
    k = n_data_shards(mesh)
    if n % k:
        raise ValueError(f"n={n} not divisible by {k} data shards")
    return k


# ---------------------------------------------------------------------------
# Sharded one-touch ladder precompute (the padded engine's mesh= path)
# ---------------------------------------------------------------------------

def shard_level_grams(
    provider: LevelGramProvider,
    keys: jax.Array,
    q: Quadratic,
    ladder: tuple[int, ...],
    mesh: Mesh,
    compute_dtype: str | None = None,
) -> jnp.ndarray:
    """(L, B, d, d) ladder-level Grams of the *concatenated* block sketch.

    Each data shard runs the family's one-touch pass — streamed gaussian /
    sjlt fold / srht FWHT — on its local row block A_k with independent
    randomness ``fold_in(keys[b], shard_index)``, producing the local
    partial Grams (S_m^{(k)} A_k)ᵀ(S_m^{(k)} A_k) at every ladder level;
    ONE psum over the data axes yields the global Grams, because the
    concatenated sketch S_m = blockdiag(S_m^{(1)}, …, S_m^{(K)}) has

        (S_m A)ᵀ(S_m A) = Σ_k (S_m^{(k)} A_k)ᵀ(S_m^{(k)} A_k)

    exactly (no cross terms), and each block is already correctly
    normalized (Gaussian entries N(0, 1/m); E[S_kᵀS_k] = I for SJLT/SRHT)
    so NO per-shard rescale is applied (DESIGN.md §5). Per shard nothing
    larger than the (L, B, d, d) Gram stack and the family's local
    O(B·m_max·d) row stream is materialized, and the psum payload is
    exactly L·B·d² per level stack.

    ``keys`` must be a (B,)-batch of per-problem keys (the engine splits a
    single key before calling); ``q`` must be batched, with n divisible by
    the data-shard count.

    ``compute_dtype`` (``kernels.precision``): each shard's one-touch pass
    runs at the reduced stream precision locally — bf16 operands / int8
    codes with fp32 accumulation — and returns fp32 partial Grams, so the
    ONE psum is an exact fp32 reduction in every mode ("bf16 passes, one
    fp32 psum"): the cross-shard sum adds no reduced-precision error.
    """
    if not q.batched:
        raise ValueError("shard_level_grams expects a batched Quadratic")
    da = data_axes(mesh)
    _check_divisible(q.n, mesh)
    m_max = ladder[-1]
    weighted = q.row_weights is not None

    def local_pass(A_blk, w_blk, b, nu, lam, ks):
        idx = jax.lax.axis_index(da)
        k_loc = jax.vmap(lambda k: jax.random.fold_in(k, idx))(ks)
        # each shard's one-touch pass sketches W^{1/2}_blk · A_blk locally:
        # the weight is row-diagonal, so it splits over row blocks exactly
        # like A does and the concatenated-block Gram identity is unchanged
        q_loc = Quadratic(A=A_blk, b=b, nu=nu, lam_diag=lam, batched=True,
                          row_weights=w_blk)
        sample_dtype = (A_blk.dtype if A_blk.dtype != jnp.int8
                        else jnp.float32)
        data = provider.sample(k_loc, m_max, A_blk.shape[-2], sample_dtype)
        g = provider.level_grams(data, q_loc, ladder,
                                 compute_dtype=compute_dtype)
        return jax.lax.psum(g, axis_name=da)

    if weighted:
        fn = _smap(
            local_pass, mesh,
            in_specs=(_a_row_spec(q, mesh), _w_row_spec(q, mesh),
                      P(), P(), P(), P()),
            out_specs=P(),
        )
        return fn(q.A, q.row_weights, q.b, q.nu, q.lam_diag, keys)
    fn = _smap(
        lambda A_blk, b, nu, lam, ks: local_pass(A_blk, None, b, nu, lam, ks),
        mesh,
        in_specs=(_a_row_spec(q, mesh), P(), P(), P(), P()),
        out_specs=P(),
    )
    return fn(q.A, q.b, q.nu, q.lam_diag, keys)


def shard_level_grams_per_shard(
    provider: LevelGramProvider,
    keys: jax.Array,
    q: Quadratic,
    ladder: tuple[int, ...],
    mesh: Mesh,
    compute_dtype: str | None = None,
) -> jnp.ndarray:
    """(K, L, B, d, d) PER-SHARD ladder-level Gram contributions — the same
    one-touch pass as ``shard_level_grams`` but all-gathered instead of
    psummed, so the caller keeps each shard's partial sum separately
    (leading axis ordered by ``axis_index``). This is the elastic-recovery
    precompute (DESIGN.md §11): the total is the exact psum result
    (``(SA)ᵀ(SA) = Σ_k (S_k A_k)ᵀ(S_k A_k)``, no cross terms), and losing
    shard k mid-solve recombines by ONE subtraction of a cached (L, B, d, d)
    stack — no surviving shard re-reads a byte of its data. Memory is K×
    the psum path's Gram stack, host-held by ``ShardLadderCache``."""
    if not q.batched:
        raise ValueError("shard_level_grams_per_shard expects a batched "
                         "Quadratic")
    da = data_axes(mesh)
    _check_divisible(q.n, mesh)
    m_max = ladder[-1]
    weighted = q.row_weights is not None

    def local_pass(A_blk, w_blk, b, nu, lam, ks):
        idx = jax.lax.axis_index(da)
        k_loc = jax.vmap(lambda k: jax.random.fold_in(k, idx))(ks)
        q_loc = Quadratic(A=A_blk, b=b, nu=nu, lam_diag=lam, batched=True,
                          row_weights=w_blk)
        sample_dtype = (A_blk.dtype if A_blk.dtype != jnp.int8
                        else jnp.float32)
        data = provider.sample(k_loc, m_max, A_blk.shape[-2], sample_dtype)
        g = provider.level_grams(data, q_loc, ladder,
                                 compute_dtype=compute_dtype)
        return g[None]                     # (1, L, B, d, d) local slice

    out_specs = P(da, None, None, None, None)
    if weighted:
        fn = _smap(
            local_pass, mesh,
            in_specs=(_a_row_spec(q, mesh), _w_row_spec(q, mesh),
                      P(), P(), P(), P()),
            out_specs=out_specs,
        )
        return fn(q.A, q.row_weights, q.b, q.nu, q.lam_diag, keys)
    fn = _smap(
        lambda A_blk, b, nu, lam, ks: local_pass(A_blk, None, b, nu, lam, ks),
        mesh,
        in_specs=(_a_row_spec(q, mesh), P(), P(), P(), P()),
        out_specs=out_specs,
    )
    return fn(q.A, q.b, q.nu, q.lam_diag, keys)


class ShardLadderCache:
    """Cached per-shard ladder-level Gram contributions + their running
    total — the state behind elastic mid-solve shard recovery.

    Built once from the SAME one-touch pass the engine would run
    (``from_mesh``: the sharded pass, all-gathered per shard;
    ``from_emulation``: the single-device ``BlockEmulationProvider``
    dataflow — identical per-shard ``fold_in(key, k)`` randomness, so the
    cache total matches the provider's summed Grams). ``total()`` feeds
    ``prepare_padded_solve(grams=…)`` / the segmented driver's ``grams=``;
    when shard k dies mid-solve, ``drop(k)`` updates the total by ONE
    (L, B, d, d) subtraction — surviving shards' data is never touched
    again — and the new total goes to ``reprecondition_padded`` via the
    driver's ``on_segment`` hook (``ft.faults.ShardLossInjector`` wires
    exactly that for the chaos suite).

    The post-drop total is the exact concatenated-block sketch Gram of the
    surviving K−1 shards: still a valid (merely weaker) preconditioner of
    the FULL problem, whose Hessian never referenced the cache at all — so
    the resumed solve's certificate stays truthful."""

    def __init__(self, shard_grams: jnp.ndarray):
        if shard_grams.ndim != 5:
            raise ValueError(
                f"expected (K, L, B, d, d) shard Grams, got shape "
                f"{tuple(shard_grams.shape)}")
        self.shard_grams = shard_grams
        self.n_shards = int(shard_grams.shape[0])
        self.alive = set(range(self.n_shards))
        # sequential accumulation in shard order — the same fp32 reduction
        # order as BlockEmulationProvider's summed pass, so the emulated
        # cache total is bit-identical to the provider's Grams
        total = shard_grams[0]
        for k in range(1, self.n_shards):
            total = total + shard_grams[k]
        self._total = total

    @classmethod
    def from_mesh(cls, provider, keys, q: Quadratic, ladder, mesh: Mesh,
                  compute_dtype: str | None = None) -> "ShardLadderCache":
        from .level_grams import get_provider

        grams = shard_level_grams_per_shard(
            get_provider(provider), keys, q, ladder, mesh,
            compute_dtype=compute_dtype)
        return cls(grams)

    @classmethod
    def from_emulation(cls, inner, keys, q: Quadratic, ladder,
                       n_shards: int,
                       compute_dtype: str | None = None) -> "ShardLadderCache":
        """Single-device build mirroring ``BlockEmulationProvider``: shard k
        sketches rows [k·n/K, (k+1)·n/K) under ``fold_in(keys, k)``."""
        from .level_grams import get_provider

        inner = get_provider(inner)
        if q.n % n_shards:
            raise ValueError(
                f"n={q.n} not divisible by {n_shards} emulated shards")
        n_loc = q.n // n_shards
        sample_dtype = q.A.dtype if q.A.dtype != jnp.int8 else jnp.float32
        w = q.row_weights
        per_shard = []
        for k in range(n_shards):
            keys_k = jax.vmap(lambda kb: jax.random.fold_in(kb, k))(keys)
            data = inner.sample(keys_k, ladder[-1], n_loc, sample_dtype)
            A_k = q.A[..., k * n_loc:(k + 1) * n_loc, :]
            w_k = None if w is None else w[:, k * n_loc:(k + 1) * n_loc]
            q_k = Quadratic(A=A_k, b=q.b, nu=q.nu, lam_diag=q.lam_diag,
                            batched=q.batched, row_weights=w_k)
            per_shard.append(inner.level_grams(
                data, q_k, ladder, compute_dtype=compute_dtype))
        return cls(jnp.stack(per_shard, axis=0))

    def total(self) -> jnp.ndarray:
        """(L, B, d, d) level Grams summed over the shards still alive."""
        return self._total

    def drop(self, k: int) -> jnp.ndarray:
        """Shard k died: remove its cached contribution from the total by
        one subtraction (no re-touch of any surviving shard's rows) and
        return the recombined (L, B, d, d) Grams."""
        if k not in self.alive:
            raise ValueError(
                f"shard {k} is not alive (alive: {sorted(self.alive)})")
        if len(self.alive) <= 1:
            raise ValueError("cannot drop the last remaining shard")
        self.alive.discard(k)
        self._total = self._total - self.shard_grams[k]
        return self._total


def shard_weighted_gram(q: Quadratic, mesh: Mesh) -> jnp.ndarray:
    """(B, d, d) AᵀWA for a row-sharded weighted batch: each shard runs the
    chunked streaming Gram (``quadratic.weighted_gram``) on its local row
    block — no (n, d) weighted copy of A anywhere — and ONE psum combines
    the block Grams (AᵀWA = Σ_k A_kᵀW_kA_k exactly: W is row-diagonal)."""
    from .quadratic import weighted_gram

    if not q.batched or q.row_weights is None:
        raise ValueError("shard_weighted_gram expects a batched, weighted "
                         "Quadratic")
    da = data_axes(mesh)
    _check_divisible(q.n, mesh)

    def local_gram(A_blk, w_blk):
        return jax.lax.psum(weighted_gram(A_blk, w_blk), axis_name=da)

    fn = _smap(local_gram, mesh,
               in_specs=(_a_row_spec(q, mesh), _w_row_spec(q, mesh)),
               out_specs=P())
    return fn(q.A, q.row_weights)


def sharded_padded_solve(q: Quadratic, keys: jax.Array, mesh: Mesh, **kw):
    """GSPMD path: place a batched problem's A over the mesh's data axes
    and run the padded adaptive engine with the sharded one-touch
    precompute (``mesh=`` swaps only the provider call; the while_loop is
    unchanged and the in-loop hvp's AᵀA·v reduction — when ``gram_hvp`` is
    off — is the only per-iteration collective)."""
    from .adaptive_padded import padded_adaptive_solve_batched

    qd = shard_quadratic(q, mesh)
    return padded_adaptive_solve_batched(qd, keys, mesh=mesh, **kw)


# ---------------------------------------------------------------------------
# Explicit shard_map path for the summed block sketch + factorize
# ---------------------------------------------------------------------------

def block_sketch_gram(
    A: jnp.ndarray,
    key: jax.Array,
    kind: str,
    m: int,
    mesh: Mesh,
    *,
    s: int = 1,
):
    """Compute SA = Σ_k S_k A_k with per-shard randomness, under shard_map.

    Returns the replicated (m, d) sketched matrix. The per-shard sketch uses
    ``jax.random.fold_in(key, shard_index)`` so shards are independent, and
    the row budget m is kept global (each shard contributes to all m rows —
    this is summing sketches, not concatenating). No rescale is applied:
    each S_k has E[S_kᵀS_k] = I on its block and the blocks are independent
    and zero-mean, so E[(SA)ᵀSA] = Σ_k A_kᵀA_k = AᵀA already. (A previous
    revision divided by √K, which shrank the sketched Gram — and therefore
    the AᵀA part of the preconditioner H_S — K-fold; the regression test in
    tests/test_sharded.py pins the corrected normalization.)
    """
    da = data_axes(mesh)
    _check_divisible(A.shape[0], mesh)

    def local_sketch(A_blk: jnp.ndarray) -> jnp.ndarray:
        idx = jax.lax.axis_index(da)
        k = jax.random.fold_in(key, idx)
        sk = make_sketch(kind, m, A_blk.shape[0], k, dtype=A_blk.dtype, s=s)
        return jax.lax.psum(sk.apply(A_blk), axis_name=da)

    fn = _smap(local_sketch, mesh, in_specs=P(da, None), out_specs=P())
    return fn(A)


def distributed_sketch_and_factorize(
    q: Quadratic, key: jax.Array, kind: str, m: int, mesh: Mesh, *, s: int = 1
):
    """Block sketch + replicated factorization of H_S."""
    SA = block_sketch_gram(q.A, key, kind, m, mesh, s=s)
    return factorize(SA, q.nu, q.lam_diag)


# ---------------------------------------------------------------------------
# GSPMD shardings (used by dryrun / launch): jit the plain Quadratic ops with
# these and XLA inserts the data-axis collectives.
# ---------------------------------------------------------------------------

def quadratic_shardings(mesh: Mesh, q: Quadratic | None = None) -> Quadratic:
    """Sharding pytree matching Quadratic: A row-sharded, rest replicated.

    Pass ``q`` to pick the batched layouts (per-problem A shards axis 1);
    without it the single-problem (n, d) layout is assumed."""
    da = data_axes(mesh)
    a_spec = _a_row_spec(q, mesh) if q is not None else P(da, None)
    batched = bool(q.batched) if q is not None else False
    weighted = q is not None and q.row_weights is not None
    return Quadratic(
        A=NamedSharding(mesh, a_spec),
        b=NamedSharding(mesh, P()),
        nu=NamedSharding(mesh, P()),
        lam_diag=NamedSharding(mesh, P()),
        batched=batched,
        row_weights=(NamedSharding(mesh, _w_row_spec(q, mesh))
                     if weighted else None),
    )
