"""Random embeddings (sketches) for the adaptive preconditioner.

Implements the three families used in the paper (§2.1):

* Gaussian embeddings — i.i.d. N(0, 1/m) entries.
* SRHT  — subsampled randomized Hadamard transform  S = R·H·E, with the
  FWHT computed by the Pallas kernel (``repro.kernels.fwht``) on TPU and a
  pure-jnp oracle elsewhere.
* SJLT  — sparse Johnson-Lindenstrauss transform with ``s`` non-zeros per
  column (default s=1, the paper's choice), lowered to a one-hot MXU matmul
  on TPU (see DESIGN.md §3).

All sketches expose a single functional entry point::

    sketch = make_sketch(kind, m, n, key, s=...)
    SA = sketch.apply(A)          # (m, d) — works under shard_map with A
                                  # row-sharded; callers psum over 'data'.

Sketch application is linear, so for a row-sharded A = [A_1; ...; A_K] the
global sketch is the sum of per-shard partial sketches with *independent*
per-shard randomness (block sketching) — see ``distributed.py``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

import jax
import jax.numpy as jnp

SketchKind = Literal["gaussian", "srht", "sjlt"]


def _next_pow2(n: int) -> int:
    return 1 << max(0, (n - 1).bit_length())


# ---------------------------------------------------------------------------
# FWHT (pure-jnp reference used on CPU; Pallas kernel used on TPU via ops.py)
# ---------------------------------------------------------------------------

def fwht(x: jnp.ndarray, axis: int = -1) -> jnp.ndarray:
    """Unnormalized fast Walsh–Hadamard transform along ``axis``.

    Length along ``axis`` must be a power of two. O(n log n) butterflies
    expressed as reshapes so XLA fuses them; used as the reference
    implementation and the CPU execution path.
    """
    x = jnp.moveaxis(x, axis, -1)
    n = x.shape[-1]
    if n & (n - 1):
        raise ValueError(f"FWHT length must be a power of 2, got {n}")
    orig_shape = x.shape
    h = 1
    while h < n:
        x = x.reshape(orig_shape[:-1] + (n // (2 * h), 2, h))
        a = x[..., 0, :]
        b = x[..., 1, :]
        x = jnp.concatenate([a + b, a - b], axis=-1)
        x = x.reshape(orig_shape)
        h *= 2
    return jnp.moveaxis(x, -1, axis)


# ---------------------------------------------------------------------------
# Sketch container
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Sketch:
    """A sampled random embedding S ∈ R^{m×n}, applied matrix-free."""

    kind: str
    m: int
    n: int
    # Gaussian: dense (m, n). SRHT: signs (n,), rows (m,). SJLT: rows (s, n),
    # signs (s, n).
    data: dict

    def tree_flatten(self):
        return (self.data,), (self.kind, self.m, self.n)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, m, n = aux
        return cls(kind=kind, m=m, n=n, data=children[0])

    # -- application ------------------------------------------------------
    def apply(self, A: jnp.ndarray) -> jnp.ndarray:
        """Compute S @ A for A of shape (n, d) (or (n,) vector)."""
        squeeze = A.ndim == 1
        if squeeze:
            A = A[:, None]
        out = _APPLY[self.kind](self, A)
        return out[:, 0] if squeeze else out

    def apply_t(self, Y: jnp.ndarray) -> jnp.ndarray:
        """Compute S.T @ Y for Y of shape (m, d)."""
        squeeze = Y.ndim == 1
        if squeeze:
            Y = Y[:, None]
        out = _APPLY_T[self.kind](self, Y)
        return out[:, 0] if squeeze else out

    def dense(self) -> jnp.ndarray:
        """Materialize S (testing only)."""
        return self.apply(jnp.eye(self.n)).reshape(self.m, self.n)


# -- Gaussian ---------------------------------------------------------------

def _gaussian_sample(key, m, n, dtype) -> dict:
    S = jax.random.normal(key, (m, n), dtype=dtype) / jnp.sqrt(
        jnp.asarray(m, dtype)
    )
    return {"S": S}


def _gaussian_apply(sk: Sketch, A):
    return sk.data["S"] @ A


def _gaussian_apply_t(sk: Sketch, Y):
    return sk.data["S"].T @ Y


# -- SRHT ---------------------------------------------------------------------

def _srht_sample(key, m, n, dtype) -> dict:
    k_sign, k_rows = jax.random.split(key)
    n_pad = _next_pow2(n)
    signs = jax.random.rademacher(k_sign, (n,), dtype=dtype)
    # Sample m rows of H without replacement; in the block-sketch regime a
    # shard may have m > n_pad local rows — fall back to with-replacement
    # (still an unbiased isometry in expectation).
    rows = jax.random.choice(k_rows, n_pad, shape=(m,), replace=m > n_pad)
    return {"signs": signs, "rows": rows}


def _srht_apply(sk: Sketch, A):
    n_pad = _next_pow2(sk.n)
    X = A * sk.data["signs"][:, None]
    if n_pad != sk.n:
        X = jnp.pad(X, ((0, n_pad - sk.n), (0, 0)))
    HX = fwht(X, axis=0) / jnp.sqrt(jnp.asarray(n_pad, X.dtype))
    sub = HX[sk.data["rows"], :]
    return sub * jnp.sqrt(jnp.asarray(n_pad / sk.m, X.dtype))


def _srht_apply_t(sk: Sketch, Y):
    n_pad = _next_pow2(sk.n)
    Z = jnp.zeros((n_pad, Y.shape[1]), Y.dtype)
    Z = Z.at[sk.data["rows"], :].set(Y)
    HZ = fwht(Z, axis=0) / jnp.sqrt(jnp.asarray(n_pad, Y.dtype))
    HZ = HZ[: sk.n, :]
    return HZ * sk.data["signs"][:, None] * jnp.sqrt(
        jnp.asarray(n_pad / sk.m, Y.dtype)
    )


# -- SJLT ---------------------------------------------------------------------

def _sjlt_sample(key, m, n, dtype, s: int = 1) -> dict:
    k_rows, k_sign = jax.random.split(key)
    # For each column of S (each of the n data rows), choose s target rows
    # without replacement within the column. Sampling "without replacement"
    # per column for small s: use independent uniforms for s=1; for s>1 take
    # top-s of random keys (Gumbel trick) which is O(n·m)-free.
    if s == 1:
        rows = jax.random.randint(k_rows, (1, n), 0, m)
    else:
        g = jax.random.uniform(k_rows, (n, m))
        rows = jnp.argsort(g, axis=1)[:, :s].T  # (s, n)
    signs = jax.random.rademacher(k_sign, (s, n), dtype=dtype) / jnp.sqrt(
        jnp.asarray(s, dtype)
    )
    return {"rows": rows, "signs": signs}


def _sjlt_apply(sk: Sketch, A):
    # SA[r, :] = sum_{i: row(i)=r} sign(i) * A[i, :]  — a segment-sum. On TPU
    # the kernels/sjlt.py Pallas kernel lowers this to one-hot MXU matmuls;
    # here we use jnp segment_sum (efficient gather/scatter on CPU, and the
    # oracle for the kernel).
    rows, signs = sk.data["rows"], sk.data["signs"]
    out = jnp.zeros((sk.m, A.shape[1]), A.dtype)
    for j in range(rows.shape[0]):  # s is a small static constant
        out = out + jax.ops.segment_sum(
            A * signs[j][:, None], rows[j], num_segments=sk.m
        )
    return out


def _sjlt_apply_t(sk: Sketch, Y):
    rows, signs = sk.data["rows"], sk.data["signs"]
    out = jnp.zeros((sk.n, Y.shape[1]), Y.dtype)
    for j in range(rows.shape[0]):
        out = out + signs[j][:, None] * Y[rows[j], :]
    return out


_SAMPLERS = {
    "gaussian": _gaussian_sample,
    "srht": _srht_sample,
    "sjlt": _sjlt_sample,
}
_APPLY = {
    "gaussian": _gaussian_apply,
    "srht": _srht_apply,
    "sjlt": _sjlt_apply,
}
_APPLY_T = {
    "gaussian": _gaussian_apply_t,
    "srht": _srht_apply_t,
    "sjlt": _sjlt_apply_t,
}


def make_sketch(
    kind: SketchKind,
    m: int,
    n: int,
    key: jax.Array,
    *,
    dtype=jnp.float32,
    s: int = 1,
) -> Sketch:
    if kind not in _SAMPLERS:
        raise ValueError(f"unknown sketch kind {kind!r}")
    kwargs = {"s": s} if kind == "sjlt" else {}
    data = _SAMPLERS[kind](key, m, n, dtype, **kwargs)
    return Sketch(kind=kind, m=m, n=n, data=data)


def sketch_cost_flops(kind: SketchKind, m: int, n: int, d: int, s: int = 1) -> float:
    """Sketching cost model used by the complexity benchmarks (Table 2)."""
    if kind == "gaussian":
        return 2.0 * m * n * d
    if kind == "srht":
        n_pad = _next_pow2(n)
        return 2.0 * n_pad * math.log2(max(2, n_pad)) * d
    if kind == "sjlt":
        return 2.0 * s * n * d
    raise ValueError(kind)
