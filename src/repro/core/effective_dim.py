"""Effective dimension and critical sketch sizes (paper §1, §2.2, §5).

d_e = tr(Aν)/‖Aν‖₂ with Aν = AᵀA(AᵀA + ν²Λ)⁻¹. For Λ = I and singular
values σ_i of A:   d_e = Σ σ_i²/(σ_i²+ν²) · (σ_1²+ν²)/σ_1².

Also the critical-sketch-size formulas of Table 1 / Theorem 5.1 used to
*predict* (not run) the adaptive controller, and by the benchmarks.
"""

from __future__ import annotations

import math

import jax.numpy as jnp


def effective_dimension(singular_values: jnp.ndarray, nu: float) -> jnp.ndarray:
    """d_e from the σ_i of A (Λ = I_d)."""
    s2 = singular_values**2
    ratios = s2 / (s2 + nu**2)
    return jnp.sum(ratios) / jnp.max(ratios)

def effective_dimension_exact(A: jnp.ndarray, nu: float, lam_diag=None) -> float:
    """d_e by direct eigen-decomposition (testing / small problems only)."""
    d = A.shape[1]
    lam = jnp.ones((d,), A.dtype) if lam_diag is None else lam_diag
    G = A.T @ A
    M = G @ jnp.linalg.inv(G + (nu**2) * jnp.diag(lam))
    eig = jnp.linalg.eigvalsh(0.5 * (M + M.T))
    return float(jnp.sum(eig) / jnp.max(eig))


def effective_dimension_weighted_exact(A: jnp.ndarray, w: jnp.ndarray,
                                       nu: float, lam_diag=None) -> float:
    """d_e(W) = tr(M)/‖M‖₂ for M = AᵀWA (AᵀWA + ν²Λ)⁻¹ — the effective
    dimension governing the sketch size of a *weighted* system, i.e. the
    GLM Newton subproblem at weights w = ℓ''(t, y) (DESIGN.md §8). Along a
    Newton path this drifts with W(x_t), which is exactly what the warm-
    started ladder of ``core.newton`` tracks instead of recomputing.

    Direct eigen-decomposition: testing / benchmarks / small problems only
    (the solver never needs d_e — it discovers m adaptively), so
    materializing W^{1/2}A and delegating through AᵀWA = (W^{1/2}A)ᵀW^{1/2}A
    is fine here — one copy of the eigen/trace logic."""
    return effective_dimension_exact(jnp.sqrt(w)[:, None] * A, nu, lam_diag)


# -- Critical sketch sizes (Table 1 / Thm 5.1), with explicit constants -------

def m_delta_srht(d_e: float, n: int, delta: float = 0.1) -> float:
    """Theorem 5.1:  m_δ = 16 log(16 d_e/δ) (√d_e + √(8 log(2n/δ)))²."""
    d_e = max(d_e, 1.0)
    return 16.0 * math.log(16.0 * d_e / delta) * (
        math.sqrt(d_e) + math.sqrt(8.0 * math.log(2.0 * n / delta))
    ) ** 2


def m_delta_gaussian(d_e: float, delta: float = 0.1) -> float:
    """Theorem 5.2:  m_δ = (√d_e + √(8 log(16/δ)))²."""
    return (math.sqrt(max(d_e, 1.0)) + math.sqrt(8.0 * math.log(16.0 / delta))) ** 2


def m_delta_sjlt(d_e: float, delta: float = 0.1) -> float:
    """Table 1: O(d_e²/δ) — the paper states only the order, leaving the
    leading constant implicit; this implementation takes it to be EXACTLY 1.

    That choice is load-bearing wherever m_delta_sjlt is compared against
    a *measured* critical sketch size (benchmarks/table1_mdelta.py,
    benchmarks/bench_newton.py): with constant 1 the d_e²/δ form is a
    conservative upper bound on every grid point we measure, but a
    different constant would shift the "theory" column verbatim — the
    benchmark call sites repeat this caveat so the comparison is never
    read as a sharp prediction."""
    return max(d_e, 1.0) ** 2 / delta


M_DELTA = {
    "srht": lambda d_e, n, delta: m_delta_srht(d_e, n, delta),
    "gaussian": lambda d_e, n, delta: m_delta_gaussian(d_e, delta),
    "sjlt": lambda d_e, n, delta: m_delta_sjlt(d_e, delta),
}


def exp_decay_singular_values(d: int, rate: float = 0.995) -> jnp.ndarray:
    """σ_j = rate^j, the paper's synthetic spectrum (§6)."""
    return rate ** jnp.arange(1, d + 1, dtype=jnp.float32)
