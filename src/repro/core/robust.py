"""Retry / fallback / preemption driver over the padded adaptive engine
(DESIGN.md §9, §11).

``padded_adaptive_solve_batched`` with ``guards=True`` terminates every
problem with a truthful per-problem verdict — but the engine itself never
*recovers* a failed problem: a stall at the ladder cap or a poisoned ladder
is terminal within one sketch draw. This module adds the host-side policy
layer that turns those engine failures into finished answers:

1. **Retry with a redrawn sketch.** An engine failure (``STALLED`` /
   ``LEVEL_INVALID`` / ``NAN_POISONED``) is, for clean data, most likely a
   bad draw — the adaptive theory (arXiv 2006.05874) only bounds the
   failure probability per draw. Failed problems are gathered into a
   padded sub-batch of the SAME (B, …) shape (unused slots get b = 0 and
   converge at x₀, so the retry reuses the already-compiled executable),
   their keys are redrawn with ``fold_in(key, retry)``, and the ladder is
   warm-started at the level the failed attempt reached (the PR 5
   ``init_level`` hook — a retry should not re-climb a ladder it already
   paid for). Bounded at ``max_retries``; a retry that converges is
   reported ``RETRIED`` with its attempt count, and a retry that merely
   improves δ̃ is adopted as the new best iterate while remaining failed.

2. **Graceful degradation.** Problems still failed after the retry budget
   go to the dense ``direct_solve`` oracle (host path, O(nd²+d³) — rare by
   construction). A finite direct answer is adopted with status
   ``FELL_BACK`` and a NaN δ̃ (the fallback carries no sketched
   certificate); a non-finite one (truly poisoned data — no solver can fix
   a NaN row) keeps the engine's best finite iterate and its honest
   engine verdict.

3. **Segmented execution** (``segmented_padded_solve_batched``): the same
   solve run as bounded segments of k loop trips per dispatch, with the
   host checking wall-clock, preemption signals and shard health BETWEEN
   segments. Because the segment executable is the monolithic while_loop
   body under a traced trip limit and the full ``PaddedState`` round-trips
   on device, a segmented solve is bitwise the monolithic one — what makes
   the three recoveries honest:

   * **deadlines** — ``deadline_s=`` stops dispatching once the budget is
     spent and finalizes the PAUSED state: unfinished problems return
     their best finite iterate, its real δ̃ certificate, and an honest
     ``DEADLINE_EXCEEDED``; problems that finished in time keep their
     verdicts untouched.
   * **preemption/crash** — ``preempt=`` (an ``ft.PreemptionHandler``) is
     polled between segments; on SIGTERM the state is checkpointed through
     ``ft.checkpoint.CheckpointManager`` (``checkpoint=``, atomic
     COMMITTED-marker layout) and ``PreemptedError`` is raised. A
     restarted process (``resume=True``, the default) restores the last
     committed segment and continues — numerics match an uninterrupted
     run because the state IS the progress (the precompute is
     deterministic given (q, keys) and is recomputed, not persisted).
     Periodic saves (``checkpoint_every``) bound the kill -9 replay to
     ``checkpoint_every·segment_trips`` trips.
   * **elastic shard loss** — ``on_segment(seg, st)`` may return
     replacement ladder level Grams (recombined from surviving shards by
     ``distributed.ShardLadderCache`` — one subtraction, no re-touch of
     surviving data); the driver then ``reprecondition``s mid-solve and
     the solve finishes ``OK`` with a truthful certificate, because only
     the preconditioner weakened — the true Hessian never referenced the
     lost shard (``gram_hvp`` serving default).

``robust_padded_solve_batched`` composes 1–3: any of the segmentation
knobs routes the first attempt (and deadline-bounded retries) through the
segmented driver; with none set, the monolithic single-dispatch path is
used unchanged (bit-compat with PR 6).

The invariant downstream layers rely on: **the returned x is always
finite, and the status tells the truth about where it came from.**
"""

from __future__ import annotations

import dataclasses
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive_padded import (
    PaddedState,
    _field_dtype,
    _is_single_key,
    doubling_ladder,
    finalize_padded_solve,
    padded_adaptive_solve_batched,
    padded_solve_segment,
    padded_trip_cap,
    prepare_padded_solve,
    prepare_path_ladder,
    reprecondition_padded,
)
from .quadratic import Quadratic, direct_solve
from .status import CONVERGED_STATUSES, ENGINE_FAILURES, SolveStatus

DEFAULT_SEGMENT_TRIPS = 32


class PreemptedError(RuntimeError):
    """A solve was preempted (SIGTERM) between segments. The state was
    checkpointed (when a checkpoint manager was attached) before raising,
    so a restarted process resumes from ``segment`` exactly."""

    def __init__(self, segment: int, checkpoint_dir=None):
        self.segment = segment
        self.checkpoint_dir = checkpoint_dir
        where = f" (checkpointed to {checkpoint_dir})" if checkpoint_dir else ""
        super().__init__(
            f"solve preempted at segment {segment}{where}; "
            f"re-run with resume=True to continue")


def _gather_quadratic(q: Quadratic, idx: jax.Array,
                      dead_mask: np.ndarray | None = None) -> Quadratic:
    """Sub-batch q[idx]; slots where ``dead_mask`` is True get b = 0 so the
    engine converges on them at x₀ (padding lanes of a retry batch)."""
    b = q.b[idx]
    if dead_mask is not None:
        b = jnp.where(jnp.asarray(dead_mask)[:, None], jnp.zeros_like(b), b)
    return Quadratic(
        A=q.A if q.shared_A else q.A[idx],
        b=b,
        nu=q.nu[idx],
        lam_diag=q.lam_diag[idx],
        batched=True,
        row_weights=None if q.row_weights is None else q.row_weights[idx],
    )


def _as_checkpoint_manager(checkpoint):
    """Accept a ready CheckpointManager (duck-typed) or a directory path.
    The ft import stays function-local: core must not import ft at module
    level (ft layers on top of core)."""
    if checkpoint is None or hasattr(checkpoint, "latest_step"):
        return checkpoint
    if isinstance(checkpoint, (str, os.PathLike)):
        from repro.ft.checkpoint import CheckpointManager

        return CheckpointManager(checkpoint)
    raise TypeError(
        f"checkpoint must be a CheckpointManager or a path, got "
        f"{type(checkpoint).__name__}")


def _solve_fingerprint(q: Quadratic, *, m_max, method, sketch,
                       max_iters) -> str:
    """Guards a resume against a checkpoint from a DIFFERENT solve: the
    restored state only means something under the same problem shapes and
    the same (deterministically recomputed) precompute."""
    sk = getattr(sketch, "name", None) or str(sketch)
    return (f"{q.batch}x{q.n}x{q.d}:m{m_max}:{method}:{sk}:mi{max_iters}")


def segmented_padded_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    method: str = "pcg",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    guards: bool = True,
    compute_dtype: str = "fp32",
    segment_trips: int = DEFAULT_SEGMENT_TRIPS,
    deadline_s: float | None = None,
    checkpoint=None,
    checkpoint_every: int = 1,
    resume: bool = True,
    preempt=None,
    on_segment=None,
    grams: jnp.ndarray | None = None,
    gram_full: jnp.ndarray | None = None,
    x0: jnp.ndarray | None = None,
):
    """The segmented host driver (DESIGN.md §11): ``prepare`` once, then
    re-dispatch ONE compiled segment executable ``segment_trips`` loop
    trips at a time, checking preemption / deadline / shard health between
    dispatches, and ``finalize`` whatever state the loop ends in.

    Same contract and return value as ``padded_adaptive_solve_batched``
    (bitwise identical when nothing fires), plus:

    * ``deadline_s``   — wall-clock budget from entry; the first segment of
      an invocation ALWAYS runs (a resumed or retried solve with a nearly
      spent budget still makes progress), after which no further segment
      is dispatched past the deadline. Unfinished problems are finalized
      with status ``DEADLINE_EXCEEDED``, their best finite iterate and its
      real δ̃.
    * ``checkpoint``   — CheckpointManager (or directory path) that
      persists ``PaddedState._asdict()`` every ``checkpoint_every``
      segments (blocking: a committed marker must never lead the data) and
      on preemption.
    * ``resume``       — restore the last committed segment from
      ``checkpoint`` before solving (no-op when none exists). The caller
      must present the same problem and keys; a fingerprint in the
      checkpoint's ``extra`` rejects mismatched resumes loudly.
    * ``preempt``      — object with a ``should_stop`` attribute
      (``ft.PreemptionHandler``); polled between segments. When set, the
      state is checkpointed and ``PreemptedError`` raised.
    * ``on_segment``   — ``fn(segment, state) -> grams | None`` host hook;
      returning replacement (L, B, d, d) level Grams triggers a mid-solve
      ``reprecondition_padded`` (elastic shard recovery) with trip-budget
      headroom for the re-climb.
    * ``grams``        — precomputed ladder level Grams for ``prepare``
      (e.g. ``ShardLadderCache.total()`` or the path engine's shared
      λ-free ladder), skipping the sketch pass.
    * ``gram_full`` / ``x0`` — precomputed true Gram and warm-start
      iterate, forwarded to ``prepare`` (path mode, DESIGN.md §13).

    Extra stats keys: ``segments`` (dispatches this invocation),
    ``resumed`` (bool), ``deadline_hit`` (bool).
    """
    t0 = time.perf_counter()
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)

    ckpt = _as_checkpoint_manager(checkpoint)
    fingerprint = _solve_fingerprint(q, m_max=m_max, method=method,
                                     sketch=sketch, max_iters=max_iters)

    pre, st = prepare_padded_solve(
        q, keys, m_max=m_max, sketch=sketch, gram_hvp=gram_hvp, mesh=mesh,
        init_level=init_level, guards=guards, compute_dtype=compute_dtype,
        tol=tol, grams=grams, gram_full=gram_full, x0=x0)

    trip_budget = padded_trip_cap(m_max, max_iters)
    ladder_len = len(doubling_ladder(m_max))
    seg = 0
    resumed = False
    if ckpt is not None and resume and ckpt.latest_step() is not None:
        restored, extra = ckpt.restore(st._asdict())
        got = extra.get("fingerprint")
        if got != fingerprint:
            raise ValueError(
                f"checkpoint fingerprint mismatch: checkpoint is for "
                f"{got!r}, this solve is {fingerprint!r} — refusing to "
                f"resume onto a different problem")
        st = PaddedState(**restored)
        seg = int(extra.get("segment", ckpt.latest_step()))
        trip_budget = int(extra.get("trip_budget", trip_budget))
        resumed = True

    def _save(segment: int):
        ckpt.save(segment, st._asdict(),
                  extra={"segment": segment, "fingerprint": fingerprint,
                         "trip_budget": trip_budget},
                  blocking=True)

    deadline_hit = False
    seg_ran = 0
    while True:
        trips_now = int(jax.device_get(st.trips))
        if bool(np.all(jax.device_get(st.done))) or trips_now >= trip_budget:
            break
        if preempt is not None and getattr(preempt, "should_stop", False):
            if ckpt is not None:
                _save(seg)
            raise PreemptedError(seg, getattr(ckpt, "dir", None))
        if (deadline_s is not None and seg_ran > 0
                and time.perf_counter() - t0 >= deadline_s):
            deadline_hit = True
            break
        limit = min(trip_budget, trips_now + int(segment_trips))
        st = padded_solve_segment(q, pre, st, limit, method=method,
                                  max_iters=max_iters, rho=rho, tol=tol,
                                  guards=guards)
        # block so the wall-clock check above measures real solve time,
        # not dispatch time
        st = jax.block_until_ready(st)
        seg += 1
        seg_ran += 1
        if on_segment is not None:
            new_grams = on_segment(seg, st)
            if new_grams is not None:
                pre, st = reprecondition_padded(q, pre, st, new_grams,
                                                guards=guards)
                # re-anchored problems may need to re-climb the ladder
                trip_budget += ladder_len
        if ckpt is not None and seg_ran % max(1, checkpoint_every) == 0:
            _save(seg)

    x, stats = finalize_padded_solve(pre, st, m_max=m_max)
    stats = dict(stats)
    if deadline_hit:
        # every not-done problem is by construction not converged: override
        # its engine verdict with the honest one. Finished problems keep
        # theirs bit-for-bit.
        status = np.array(stats["status"])
        not_done = ~np.asarray(jax.device_get(st.done))
        status[not_done] = int(SolveStatus.DEADLINE_EXCEEDED)
        stats["status"] = jnp.asarray(status, dtype=jnp.int32)
        stats["stalled"] = jnp.asarray(status == int(SolveStatus.STALLED))
    stats["segments"] = seg_ran
    stats["resumed"] = resumed
    stats["deadline_hit"] = deadline_hit
    return x, stats


def robust_padded_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    method: str = "pcg",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    max_retries: int = 2,
    fallback: bool = True,
    compute_dtype: str = "fp32",
    deadline_s: float | None = None,
    segment_trips: int | None = None,
    checkpoint=None,
    checkpoint_every: int = 1,
    resume: bool = True,
    preempt=None,
    on_segment=None,
    grams: jnp.ndarray | None = None,
    gram_full: jnp.ndarray | None = None,
    x0: jnp.ndarray | None = None,
):
    """Solve a batch with engine guards + sketch-redraw retries + fallback.

    Same contract as ``padded_adaptive_solve_batched`` (which it calls with
    ``guards=True``), plus the recovery policy above. Returns ``(x, stats)``
    where x (B, d) is finite for every problem that admits a finite answer,
    and ``stats`` carries per-problem vectors:

    * ``status``     — final ``SolveStatus`` codes (int32)
    * ``retries``    — redraw attempts consumed (0 ⇒ first draw sufficed)
    * ``fell_back``  — bool, answer came from ``direct_solve``
    * ``converged``/``stalled`` — convenience masks over ``status``
    * engine certificates (``dtilde``, ``m_final``, ``iters`` — accumulated
      across attempts — ``doublings``, ``level``, ``invalid_levels``);
      ``dtilde`` is NaN on fallen-back slots (no sketched certificate).
    * ``segments``/``resumed``/``deadline_hit`` — segmented-driver
      telemetry (0/False on the monolithic path).

    ``max_retries=0`` disables redraws (straight to fallback);
    ``fallback=False`` disables the dense oracle — failures then keep the
    engine's best finite iterate and verdict (useful in tests and when the
    O(nd²) host path is unaffordable).

    Setting ANY of ``deadline_s`` / ``segment_trips`` / ``checkpoint`` /
    ``preempt`` / ``on_segment`` routes attempts through
    ``segmented_padded_solve_batched``. ``deadline_s`` is a wall-clock
    budget over the WHOLE call: the first attempt gets it all, each retry
    gets what remains (so a retrying slot cannot blow a deadline that
    clean slots already met), and the dense fallback is skipped once the
    budget is spent. Slots that ran out of budget mid-solve carry
    ``DEADLINE_EXCEEDED`` with their best iterate and real δ̃ — never
    retried (only engine failures are), and never overwritten by a retry
    that itself ran out of time. With none of those knobs set the path —
    and the numbers — are the single-dispatch monolithic ones.

    ``grams`` / ``gram_full`` / ``x0`` (path mode, DESIGN.md §13) apply to
    the FIRST attempt only: a precomputed λ-free ladder skips its sketch
    pass, but a retry is by definition a REDRAWN sketch — it recomputes
    fresh level Grams from its folded keys on the gathered sub-batch, so
    retry semantics are unchanged by the shared ladder.
    """
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)

    t0 = time.perf_counter()
    segmented = any(v is not None for v in
                    (deadline_s, segment_trips, checkpoint, preempt,
                     on_segment))
    seg_trips = (DEFAULT_SEGMENT_TRIPS if segment_trips is None
                 else int(segment_trips))

    def remaining():
        return (None if deadline_s is None
                else deadline_s - (time.perf_counter() - t0))

    def solve(qq, kk, lvl, *, budget, first=False):
        # the shared ladder / warm start bind to the first attempt only —
        # a retry redraws its sketch on the gathered sub-batch
        pk = (dict(grams=grams, gram_full=gram_full, x0=x0) if first
              else {})
        if not segmented:
            return padded_adaptive_solve_batched(
                qq, kk, m_max=m_max, method=method, sketch=sketch,
                max_iters=max_iters, rho=rho, tol=tol, gram_hvp=gram_hvp,
                mesh=mesh, init_level=lvl, guards=True,
                compute_dtype=compute_dtype, **pk)
        return segmented_padded_solve_batched(
            qq, kk, m_max=m_max, method=method, sketch=sketch,
            max_iters=max_iters, rho=rho, tol=tol, gram_hvp=gram_hvp,
            mesh=mesh, init_level=lvl, guards=True,
            compute_dtype=compute_dtype, segment_trips=seg_trips,
            deadline_s=budget, **pk,
            # checkpoint/preempt bind to the first attempt only: a retry is
            # a different (redrawn) solve and must not clobber — or resume
            # from — the first attempt's checkpoint
            checkpoint=checkpoint if first else None,
            checkpoint_every=checkpoint_every,
            resume=resume if first else False,
            preempt=preempt if first else None,
            on_segment=on_segment if first else None)

    x_dev, stats_dev = solve(q, keys, init_level, budget=remaining(),
                             first=True)

    x = np.array(x_dev)
    status = np.array(stats_dev["status"])
    dtilde = np.array(stats_dev["dtilde"])
    m_final = np.array(stats_dev["m_final"])
    iters = np.array(stats_dev["iters"])
    doublings = np.array(stats_dev["doublings"])
    level = np.array(stats_dev["level"])
    invalid_levels = np.array(stats_dev["invalid_levels"])
    trips = int(stats_dev["trips"])
    segments = int(stats_dev.get("segments", 0))
    resumed = bool(stats_dev.get("resumed", False))
    deadline_hit = bool(stats_dev.get("deadline_hit", False))

    retries = np.zeros(B, dtype=np.int32)
    fell_back = np.zeros(B, dtype=bool)
    failure_codes = np.array([int(s) for s in ENGINE_FAILURES])
    failed = np.isin(status, failure_codes)

    for attempt in range(1, max_retries + 1):
        fidx = np.flatnonzero(failed)
        if fidx.size == 0:
            break
        budget = remaining()
        if budget is not None and budget <= 0:
            break  # deadline spent: keep the honest engine verdicts
        # Same-shape padded gather: the retry reuses the compiled executable.
        pad = np.full(B, fidx[0], dtype=np.int64)
        pad[: fidx.size] = fidx
        live = np.zeros(B, dtype=bool)
        live[: fidx.size] = True
        idx = jnp.asarray(pad)
        q_sub = _gather_quadratic(q, idx, dead_mask=~live)
        keys_sub = jax.vmap(
            lambda k: jax.random.fold_in(k, attempt))(keys[idx])
        warm = jnp.asarray(level[pad], dtype=jnp.int32)

        x_sub, s_sub = solve(q_sub, keys_sub, warm, budget=budget)
        x_sub = np.array(x_sub)
        st_sub = np.array(s_sub["status"])
        dt_sub = np.array(s_sub["dtilde"])

        for j, g in enumerate(fidx):
            retries[g] = attempt
            iters[g] += int(np.array(s_sub["iters"])[j])
            adopted = st_sub[j] in [int(s) for s in CONVERGED_STATUSES]
            improved = np.isfinite(dt_sub[j]) and (
                not np.isfinite(dtilde[g]) or dt_sub[j] < dtilde[g])
            if adopted or improved:
                x[g] = x_sub[j]
                dtilde[g] = dt_sub[j]
                m_final[g] = np.array(s_sub["m_final"])[j]
                doublings[g] = np.array(s_sub["doublings"])[j]
                level[g] = np.array(s_sub["level"])[j]
                invalid_levels[g] = np.array(s_sub["invalid_levels"])[j]
            if int(st_sub[j]) == int(SolveStatus.DEADLINE_EXCEEDED):
                # the retry — not the problem — ran out of budget: keep the
                # previous attempt's honest engine verdict
                pass
            else:
                status[g] = (int(SolveStatus.RETRIED) if adopted
                             else int(st_sub[j]))
            failed[g] = not adopted
        trips += int(s_sub["trips"])
        segments += int(s_sub.get("segments", 0))

    fidx = np.flatnonzero(failed)
    budget = remaining()
    if fallback and fidx.size and (budget is None or budget > 0):
        q_f = _gather_quadratic(q, jnp.asarray(fidx))
        x_fb = np.array(direct_solve(q_f))
        finite = np.all(np.isfinite(x_fb), axis=-1)
        for j, g in enumerate(fidx):
            if finite[j]:
                x[g] = x_fb[j]
                status[g] = int(SolveStatus.FELL_BACK)
                fell_back[g] = True
                dtilde[g] = np.nan  # no sketched certificate on this path

    conv_codes = np.array([int(s) for s in CONVERGED_STATUSES])
    stats = {
        "status": jnp.asarray(status, dtype=jnp.int32),
        "retries": jnp.asarray(retries),
        "fell_back": jnp.asarray(fell_back),
        "converged": jnp.asarray(np.isin(status, conv_codes)),
        "stalled": jnp.asarray(status == int(SolveStatus.STALLED)),
        "dtilde": jnp.asarray(dtilde),
        "m_final": jnp.asarray(m_final),
        "iters": jnp.asarray(iters),
        "doublings": jnp.asarray(doublings),
        "level": jnp.asarray(level),
        "invalid_levels": jnp.asarray(invalid_levels),
        "trips": trips,
        "segments": segments,
        "resumed": resumed,
        "deadline_hit": deadline_hit,
    }
    return jnp.asarray(x), stats


def robust_path_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    nus: jnp.ndarray,
    *,
    m_max: int,
    method: str = "pcg",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    max_retries: int = 2,
    fallback: bool = True,
    compute_dtype: str = "fp32",
    warm_start: bool = True,
    grams: jnp.ndarray | None = None,
    gram_full: jnp.ndarray | None = None,
):
    """Regularization path with the full recovery policy per λ point.

    The robust counterpart of
    ``adaptive_padded.padded_path_solve_batched``: the λ-free ladder (and
    the true-Gram precompute) is paid ONCE via ``prepare_path_ladder`` —
    or supplied via ``grams=`` / ``gram_full=``, e.g. by the serving
    ladder cache — and every grid point runs
    ``robust_padded_solve_batched`` off it, warm-starting x and the
    per-problem ladder level from the previous point. Retry / fallback /
    ``guards`` semantics hold PER PATH POINT: a bad draw at one λ retries
    with a redrawn sketch on that point's failed slots only (each retry is
    an extra sketch pass on the gathered sub-batch, counted in
    ``sketch_passes``); fallen-back slots carry ``FELL_BACK`` with NaN δ̃
    at that point and still warm-start the next one (their x is finite).

    ``nus`` is (P,) shared or (P, B) per-problem; ``q.nu`` is ignored.
    Returns ``(xs, stats)``: xs (P, B, d); per-problem stats vectors
    stacked to (P, B); ``trips`` / ``segments`` summed over the path; and
    ``sketch_passes`` — 1 for a clean path, +1 per retry attempt."""
    if not q.batched:
        raise ValueError("robust_path_solve_batched expects a batched "
                         "Quadratic")
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)
    nus = jnp.asarray(nus, _field_dtype(q))
    if nus.ndim == 1:
        nus = jnp.broadcast_to(nus[:, None], (nus.shape[0], B))
    P = nus.shape[0]
    if grams is None:
        grams, gram_full = prepare_path_ladder(
            q, keys, m_max=m_max, sketch=sketch, gram_hvp=gram_hvp,
            mesh=mesh, compute_dtype=compute_dtype)
    xs, per_point = [], []
    x_prev, lvl = None, init_level
    sketch_passes = 1
    for p in range(P):
        q_p = dataclasses.replace(q, nu=nus[p])
        x, stats = robust_padded_solve_batched(
            q_p, keys, m_max=m_max, method=method, sketch=sketch,
            max_iters=max_iters, rho=rho, tol=tol, gram_hvp=gram_hvp,
            mesh=mesh, init_level=lvl, max_retries=max_retries,
            fallback=fallback, compute_dtype=compute_dtype,
            grams=grams, gram_full=gram_full, x0=x_prev)
        # each executed retry attempt redrew a sketch on the sub-batch
        sketch_passes += int(np.max(np.asarray(stats["retries"])))
        xs.append(x)
        per_point.append(stats)
        if warm_start:
            x_prev = x
            lvl = jnp.asarray(stats["level"], jnp.int32)
    stacked = ("status", "retries", "fell_back", "converged", "stalled",
               "dtilde", "m_final", "iters", "doublings", "level",
               "invalid_levels")
    out = {k: jnp.stack([s[k] for s in per_point]) for k in stacked}
    out["trips"] = sum(int(s["trips"]) for s in per_point)
    out["segments"] = sum(int(s["segments"]) for s in per_point)
    out["sketch_passes"] = sketch_passes
    return jnp.stack(xs), out
