"""Retry / fallback driver over the padded adaptive engine (DESIGN.md §9).

``padded_adaptive_solve_batched`` with ``guards=True`` terminates every
problem with a truthful per-problem verdict — but the engine itself never
*recovers* a failed problem: a stall at the ladder cap or a poisoned ladder
is terminal within one sketch draw. This module adds the host-side policy
layer that turns those engine failures into finished answers:

1. **Retry with a redrawn sketch.** An engine failure (``STALLED`` /
   ``LEVEL_INVALID`` / ``NAN_POISONED``) is, for clean data, most likely a
   bad draw — the adaptive theory (arXiv 2006.05874) only bounds the
   failure probability per draw. Failed problems are gathered into a
   padded sub-batch of the SAME (B, …) shape (unused slots get b = 0 and
   converge at x₀, so the retry reuses the already-compiled executable),
   their keys are redrawn with ``fold_in(key, retry)``, and the ladder is
   warm-started at the level the failed attempt reached (the PR 5
   ``init_level`` hook — a retry should not re-climb a ladder it already
   paid for). Bounded at ``max_retries``; a retry that converges is
   reported ``RETRIED`` with its attempt count, and a retry that merely
   improves δ̃ is adopted as the new best iterate while remaining failed.

2. **Graceful degradation.** Problems still failed after the retry budget
   go to the dense ``direct_solve`` oracle (host path, O(nd²+d³) — rare by
   construction). A finite direct answer is adopted with status
   ``FELL_BACK`` and a NaN δ̃ (the fallback carries no sketched
   certificate); a non-finite one (truly poisoned data — no solver can fix
   a NaN row) keeps the engine's best finite iterate and its honest
   engine verdict.

The invariant downstream layers rely on: **the returned x is always
finite, and the status tells the truth about where it came from.**
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive_padded import _is_single_key, padded_adaptive_solve_batched
from .quadratic import Quadratic, direct_solve
from .status import CONVERGED_STATUSES, ENGINE_FAILURES, SolveStatus


def _gather_quadratic(q: Quadratic, idx: jax.Array,
                      dead_mask: np.ndarray | None = None) -> Quadratic:
    """Sub-batch q[idx]; slots where ``dead_mask`` is True get b = 0 so the
    engine converges on them at x₀ (padding lanes of a retry batch)."""
    b = q.b[idx]
    if dead_mask is not None:
        b = jnp.where(jnp.asarray(dead_mask)[:, None], jnp.zeros_like(b), b)
    return Quadratic(
        A=q.A if q.shared_A else q.A[idx],
        b=b,
        nu=q.nu[idx],
        lam_diag=q.lam_diag[idx],
        batched=True,
        row_weights=None if q.row_weights is None else q.row_weights[idx],
    )


def robust_padded_solve_batched(
    q: Quadratic,
    keys: jax.Array,
    *,
    m_max: int,
    method: str = "pcg",
    sketch: str = "gaussian",
    max_iters: int = 100,
    rho: float = 0.5,
    tol: float = 1e-10,
    gram_hvp: bool | None = None,
    mesh=None,
    init_level: jax.Array | None = None,
    max_retries: int = 2,
    fallback: bool = True,
    compute_dtype: str = "fp32",
):
    """Solve a batch with engine guards + sketch-redraw retries + fallback.

    Same contract as ``padded_adaptive_solve_batched`` (which it calls with
    ``guards=True``), plus the recovery policy above. Returns ``(x, stats)``
    where x (B, d) is finite for every problem that admits a finite answer,
    and ``stats`` carries per-problem vectors:

    * ``status``     — final ``SolveStatus`` codes (int32)
    * ``retries``    — redraw attempts consumed (0 ⇒ first draw sufficed)
    * ``fell_back``  — bool, answer came from ``direct_solve``
    * ``converged``/``stalled`` — convenience masks over ``status``
    * engine certificates (``dtilde``, ``m_final``, ``iters`` — accumulated
      across attempts — ``doublings``, ``level``, ``invalid_levels``);
      ``dtilde`` is NaN on fallen-back slots (no sketched certificate).

    ``max_retries=0`` disables redraws (straight to fallback);
    ``fallback=False`` disables the dense oracle — failures then keep the
    engine's best finite iterate and verdict (useful in tests and when the
    O(nd²) host path is unaffordable).
    """
    B = q.batch
    if _is_single_key(keys):
        keys = jax.random.split(keys, B)

    solve = lambda qq, kk, lvl: padded_adaptive_solve_batched(
        qq, kk, m_max=m_max, method=method, sketch=sketch,
        max_iters=max_iters, rho=rho, tol=tol, gram_hvp=gram_hvp,
        mesh=mesh, init_level=lvl, guards=True,
        compute_dtype=compute_dtype)

    x_dev, stats_dev = solve(q, keys, init_level)

    x = np.array(x_dev)
    status = np.array(stats_dev["status"])
    dtilde = np.array(stats_dev["dtilde"])
    m_final = np.array(stats_dev["m_final"])
    iters = np.array(stats_dev["iters"])
    doublings = np.array(stats_dev["doublings"])
    level = np.array(stats_dev["level"])
    invalid_levels = np.array(stats_dev["invalid_levels"])
    trips = int(stats_dev["trips"])

    retries = np.zeros(B, dtype=np.int32)
    fell_back = np.zeros(B, dtype=bool)
    failure_codes = np.array([int(s) for s in ENGINE_FAILURES])
    failed = np.isin(status, failure_codes)

    for attempt in range(1, max_retries + 1):
        fidx = np.flatnonzero(failed)
        if fidx.size == 0:
            break
        # Same-shape padded gather: the retry reuses the compiled executable.
        pad = np.full(B, fidx[0], dtype=np.int64)
        pad[: fidx.size] = fidx
        live = np.zeros(B, dtype=bool)
        live[: fidx.size] = True
        idx = jnp.asarray(pad)
        q_sub = _gather_quadratic(q, idx, dead_mask=~live)
        keys_sub = jax.vmap(
            lambda k: jax.random.fold_in(k, attempt))(keys[idx])
        warm = jnp.asarray(level[pad], dtype=jnp.int32)

        x_sub, s_sub = solve(q_sub, keys_sub, warm)
        x_sub = np.array(x_sub)
        st_sub = np.array(s_sub["status"])
        dt_sub = np.array(s_sub["dtilde"])

        for j, g in enumerate(fidx):
            retries[g] = attempt
            iters[g] += int(np.array(s_sub["iters"])[j])
            adopted = st_sub[j] in [int(s) for s in CONVERGED_STATUSES]
            improved = np.isfinite(dt_sub[j]) and (
                not np.isfinite(dtilde[g]) or dt_sub[j] < dtilde[g])
            if adopted or improved:
                x[g] = x_sub[j]
                dtilde[g] = dt_sub[j]
                m_final[g] = np.array(s_sub["m_final"])[j]
                doublings[g] = np.array(s_sub["doublings"])[j]
                level[g] = np.array(s_sub["level"])[j]
                invalid_levels[g] = np.array(s_sub["invalid_levels"])[j]
            status[g] = (int(SolveStatus.RETRIED) if adopted
                         else int(st_sub[j]))
            failed[g] = not adopted
        trips += int(s_sub["trips"])

    fidx = np.flatnonzero(failed)
    if fallback and fidx.size:
        q_f = _gather_quadratic(q, jnp.asarray(fidx))
        x_fb = np.array(direct_solve(q_f))
        finite = np.all(np.isfinite(x_fb), axis=-1)
        for j, g in enumerate(fidx):
            if finite[j]:
                x[g] = x_fb[j]
                status[g] = int(SolveStatus.FELL_BACK)
                fell_back[g] = True
                dtilde[g] = np.nan  # no sketched certificate on this path

    conv_codes = np.array([int(s) for s in CONVERGED_STATUSES])
    stats = {
        "status": jnp.asarray(status, dtype=jnp.int32),
        "retries": jnp.asarray(retries),
        "fell_back": jnp.asarray(fell_back),
        "converged": jnp.asarray(np.isin(status, conv_codes)),
        "stalled": jnp.asarray(status == int(SolveStatus.STALLED)),
        "dtilde": jnp.asarray(dtilde),
        "m_final": jnp.asarray(m_final),
        "iters": jnp.asarray(iters),
        "doublings": jnp.asarray(doublings),
        "level": jnp.asarray(level),
        "invalid_levels": jnp.asarray(invalid_levels),
        "trips": trips,
    }
    return jnp.asarray(x), stats
