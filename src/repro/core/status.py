"""Per-problem solve statuses — the failure lattice (DESIGN.md §9).

Every request that enters the stack terminates with a finite iterate and
exactly one of these verdicts. The engine (``core.adaptive_padded``) emits
the first four; the retry/fallback driver (``core.robust``) refines failed
problems into ``RETRIED`` / ``FELL_BACK``; the serving layer
(``serve.solver_service``) adds the two admission/deadline codes that never
reach the engine at all. Codes are plain int32 values inside jitted state
(an ``IntEnum`` compares/selects fine under ``jnp.where``).

Lattice, from best to worst:

* ``OK``                — converged to tolerance under the first sketch draw.
* ``RETRIED``           — converged, but only after ≥1 sketch redraw
                          (``fold_in(key, retry)``); retry count rides in the
                          separate ``retries`` certificate.
* ``FELL_BACK``         — the adaptive engine never converged (stall at the
                          ladder cap, poisoned ladder) and the answer comes
                          from the dense ``direct_solve`` fallback instead;
                          finite and usually accurate, but carries NO δ̃
                          certificate.
* ``STALLED``           — terminated without reaching tolerance (divergence
                          stall at the ladder cap, or iteration budget
                          exhausted) and no fallback produced a finite
                          answer; the returned x is the best finite iterate
                          and δ̃ states the shortfall honestly.
* ``LEVEL_INVALID``     — every ladder level's factorization was non-finite
                          (numerically singular H_S at all sizes); nothing
                          to iterate with. Individual invalid levels are
                          *skipped*, not fatal — this code means the whole
                          ladder was unusable.
* ``NAN_POISONED``      — non-finite arithmetic was observed (NaN/Inf in the
                          data, the sketch pass, or an iterate proposal) and
                          the problem never converged; the per-problem
                          circuit breaker froze it at its best finite
                          iterate (x₀ = 0 if nothing finite ever improved).
* ``REJECTED``          — failed submit-time validation (non-finite A/y/Λ,
                          ν ≤ 0); quarantined before packing, never solved.
* ``DEADLINE_EXCEEDED`` — the wall-clock budget ran out. Two flavors,
                          distinguishable by the certificate (DESIGN.md
                          §11): if the solve DISPATCHED, the segmented
                          driver stopped it mid-solve and the answer is
                          the best finite iterate with its real δ̃ (or the
                          Newton decrement on the GLM path) — honest
                          partial progress; if the budget was spent before
                          the chunk dispatched at all, x = 0 with a NaN
                          certificate. Never retried or fallen back (only
                          engine failures are): spending more time is
                          exactly what the deadline forbids.
"""

from __future__ import annotations

from enum import IntEnum


class SolveStatus(IntEnum):
    OK = 0
    STALLED = 1
    LEVEL_INVALID = 2
    NAN_POISONED = 3
    RETRIED = 4
    FELL_BACK = 5
    REJECTED = 6
    DEADLINE_EXCEEDED = 7


#: Engine-level terminal failures — retryable with a redrawn sketch, then
#: eligible for the direct-solve fallback (core.robust).
ENGINE_FAILURES = (
    SolveStatus.STALLED,
    SolveStatus.LEVEL_INVALID,
    SolveStatus.NAN_POISONED,
)

#: Statuses whose solution converged under an adaptive sketch and carries a
#: trustworthy δ̃ certificate.
CONVERGED_STATUSES = (SolveStatus.OK, SolveStatus.RETRIED)


def status_name(code) -> str:
    """Human-readable name for a status code (int, numpy or jnp scalar)."""
    return SolveStatus(int(code)).name
