"""Adaptive sketch-size solvers — Algorithm 4.1 (prototype) / 4.2 (PCG).

The adaptive mechanism needs data-dependent *shape* changes (sketch size
doubles), which cannot live inside one jitted graph with dynamic shapes.
Production design (host-orchestrated, bounded compilation):

* The outer while-loop runs on the host. Sketch sizes are powers of two
  times ``m_init`` so at most ⌈log₂(m_max/m_init)⌉ distinct shapes exist;
  each (method, m)-shape's step function is jit-compiled once and cached by
  JAX. The inner per-iteration work (one preconditioner solve + one H·v)
  is a single jitted call.
* ``repro.core.adaptive_padded`` offers a beyond-paper alternative that
  masks rows of a max-size sketch inside ONE compiled graph (fixed shapes,
  e.g. for serving environments); see that module.

The improvement test is exactly Alg 4.1:   reject  iff
    δ̃⁺ / δ̃_I  >  c(α,ρ) · φ(ρ)^{t+1−I} ,
on reject: I ← t, m ← 2m, resample S, re-sketch, re-factorize, restart the
method at the current iterate x_t.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from . import solvers
from .precond import SketchedPrecond, factorize
from .quadratic import Quadratic
from .sketches import make_sketch


@dataclasses.dataclass(frozen=True)
class AdaptiveConfig:
    method: str = "pcg"          # "ihs" | "pcg" | "polyak"
    sketch: str = "sjlt"         # "gaussian" | "srht" | "sjlt"
    rho: float = 0.5             # Theorem 4.1 assumes ρ ∈ (0, 1/4); the
                                 # algorithm is valid for any ρ ∈ (0,1) and
                                 # ρ = 1/2 matches the paper's observed
                                 # m_final ≈ (1–5)·d_e (smaller ρ demands a
                                 # faster sustained rate ⇒ larger sketches)
    m_init: int = 1
    m_max: int | None = None     # cap; defaults to n (where the sketch is
                                 # replaced by the exact preconditioner)
    max_iters: int = 500
    tol: float = 1e-12           # stop when δ̃_t ≤ tol · δ̃_0 (Remark 4.2 notes
                                 # the theoretical gap of practical criteria)
    sjlt_s: int = 1
    dtype: Any = jnp.float32


@dataclasses.dataclass
class AdaptiveResult:
    x: jnp.ndarray
    m_final: int
    n_doublings: int
    iters: int
    m_trace: list            # sketch size after each accepted iteration
    delta_tilde_trace: list  # δ̃ after each accepted iteration
    resketch_times: list     # host seconds spent (sketch+factorize) per phase
    iter_times: list         # host seconds per accepted/rejected iteration


# -- jitted phase primitives (cached per (method, m, shapes)) -----------------

@partial(jax.jit, static_argnames=("method",))
def _init_state(q: Quadratic, P: SketchedPrecond, x: jnp.ndarray, method: str):
    init_fn, _ = solvers.METHODS[method]
    return init_fn(q, P, x)


@partial(jax.jit, static_argnames=("method", "rho"))
def _step_state(q: Quadratic, P: SketchedPrecond, st, method: str, rho: float):
    _, step_fn = solvers.METHODS[method]
    return step_fn(q, P, st, rho)


@partial(jax.jit, static_argnames=("kind", "m", "s"))
def _sketch_and_factorize(q: Quadratic, key, kind: str, m: int, s: int
                          ) -> SketchedPrecond:
    # Weighted problems sketch W^{1/2}A so H_S estimates AᵀWA + ν²Λ. The
    # host path may materialize the weighted matrix (it is small-scale by
    # design); the streaming-fused weighted pass is the padded engine's.
    A = (q.A if q.row_weights is None
         else jnp.sqrt(q.row_weights)[:, None] * q.A)
    if m >= q.n:
        # Graceful ceiling: S = I_n makes H_S = H exactly (one-step solve).
        return factorize(A, q.nu, q.lam_diag)
    sk = make_sketch(kind, m, q.n, key, dtype=A.dtype, s=s)
    SA = sk.apply(A)
    return factorize(SA, q.nu, q.lam_diag)


@jax.jit
def _dtilde_at(P: SketchedPrecond, g: jnp.ndarray):
    return 0.5 * jnp.sum(g * P.solve(g))


def adaptive_solve(
    q: Quadratic,
    cfg: AdaptiveConfig = AdaptiveConfig(),
    x0: jnp.ndarray | None = None,
    key: jax.Array | None = None,
) -> AdaptiveResult:
    """Algorithm 4.1 specialized by cfg.method (4.2 when method == 'pcg')."""
    if key is None:
        key = jax.random.PRNGKey(0)
    if x0 is None:
        x0 = jnp.zeros_like(q.b)
    m_max = cfg.m_max if cfg.m_max is not None else q.n
    phi, alpha = solvers.rho_to_rate(cfg.method, cfg.rho)
    c = solvers.c_alpha_rho(alpha, cfg.rho)

    m = max(1, cfg.m_init)
    key, sub = jax.random.split(key)
    t_sk = time.perf_counter()
    P = _sketch_and_factorize(q, sub, cfg.sketch, m, cfg.sjlt_s)
    P = jax.block_until_ready(P)
    resketch_times = [time.perf_counter() - t_sk]

    g0 = jax.jit(lambda q, x: q.grad(x))(q, x0)

    st = _init_state(q, P, x0, cfg.method)
    dtilde_I = float(st.delta_tilde)
    # Reference for the relative-tolerance stop: δ̃ at x0 under the CURRENT
    # sketch (re-evaluated on every resketch) — with the m=1 sketch δ̃_{x0}
    # is inflated by up to (1 + m_δ/m) (Lemma 2.2), which would make the
    # relative criterion fire far too early.
    dtilde_0 = dtilde_I
    t_rel = 0  # t − I, iterations since last restart
    n_doublings = 0
    cap_resamples = 0
    m_trace, dt_trace, iter_times = [m], [dtilde_I], []

    t = 0
    while t < cfg.max_iters:
        t_it = time.perf_counter()
        st_next = _step_state(q, P, st, cfg.method, cfg.rho)
        dtilde_next = float(jax.block_until_ready(st_next.delta_tilde))
        iter_times.append(time.perf_counter() - t_it)

        converged = dtilde_next <= cfg.tol * max(dtilde_0, 1e-300)
        threshold = c * (phi ** (t_rel + 1)) * dtilde_I
        # A non-finite δ̃⁺ (tiny-m preconditioner blow-up) must be rejected:
        # NaN compares False against everything, so test finiteness first.
        reject = (not jnp.isfinite(dtilde_next)) or dtilde_next > threshold
        if not jnp.isfinite(dtilde_next) and m >= m_max:
            # Cannot grow further; resample at the cap rather than accept NaNs.
            if cap_resamples > 3:
                break
            cap_resamples += 1
            key, sub = jax.random.split(key)
            P = _sketch_and_factorize(q, sub, cfg.sketch, m, cfg.sjlt_s)
            st = _init_state(q, P, st.x, cfg.method)
            dtilde_I = float(st.delta_tilde)
            dtilde_0 = float(_dtilde_at(P, g0))
            t_rel = 0
            continue
        if reject and not converged and m < m_max:
            # Reject: double the sketch, restart the method at current x.
            n_doublings += 1
            m = min(2 * m, m_max)
            key, sub = jax.random.split(key)
            t_sk = time.perf_counter()
            P = _sketch_and_factorize(q, sub, cfg.sketch, m, cfg.sjlt_s)
            P = jax.block_until_ready(P)
            resketch_times.append(time.perf_counter() - t_sk)
            st = _init_state(q, P, st.x, cfg.method)
            dtilde_I = float(st.delta_tilde)
            dtilde_0 = float(_dtilde_at(P, g0))
            t_rel = 0
            continue

        # Accept.
        st = st_next
        t += 1
        t_rel += 1
        m_trace.append(m)
        dt_trace.append(dtilde_next)
        if converged:
            break

    return AdaptiveResult(
        x=st.x,
        m_final=m,
        n_doublings=n_doublings,
        iters=t,
        m_trace=m_trace,
        delta_tilde_trace=dt_trace,
        resketch_times=resketch_times,
        iter_times=iter_times,
    )


def k_max(m_delta: float, rho: float, m_init: int) -> int:
    """Theorem 4.1 bound on the number of doublings."""
    import math

    return max(0, math.ceil(math.log2(max(m_delta / (m_init * rho), 1.0))))
