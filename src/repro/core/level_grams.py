"""Ladder-level Gram providers for the padded adaptive engine.

The padded engine (``core.adaptive_padded``) precomputes the sketched Gram
(S_m A)ᵀ(S_m A) at every doubling-ladder level {1, 2, 4, …, m_max} before
its while_loop starts. Each sketch family owns its ladder algebra — how a
single fixed-randomness pass over A yields a *consistent* sketch at every
level — behind one protocol (DESIGN.md §6):

* ``sample(keys, m_max, n, dtype)`` → per-problem randomness (a dict of
  (B, …) arrays), one key per problem so a batched run reproduces the
  corresponding single-problem runs;
* ``level_grams(data, q, ladder)`` → (L, B, d, d) Grams, touching A
  exactly ONCE (the paper's O(sketch) + Σ O(factorize) accounting).

The level Grams are λ-FREE: no provider reads ``q.nu`` / ``q.lam_diag``
— the ν²Λ shift enters only at factorization
(``precond.shifted_ladder_inverses``). That is what lets one ladder
stack serve an entire regularization path and the serving ladder cache
key on (A, Λ, family, dtype) alone (DESIGN.md §13).

Families:

* ``gaussian`` — *streamed*: rows are generated on the fly from a
  counter-based PRNG fused with the A contraction
  (``kernels.gaussian_gram``); S never exists in HBM, A is streamed once
  in n-chunks, live memory is O(B·m_max·d + B·d²·L). Masking = prefix of
  the i.i.d. row stream; the level-m rescale 1/√m folds into 1/m on the
  Gram.
* ``gaussian_dense`` — the same sketch entries, materialized as a
  (B, m_max, n) array and contracted by einsum. Kept as the memory
  baseline for benchmarks/tests; the streamed provider must match it to
  fp reduction error at every level.
* ``sjlt`` — each data row i carries a fixed uniform u_i and a sign; the
  level-m target row ⌊u_i·m⌋ is exactly uniform for every m, and
  ⌊u·m⌋ = ⌊⌊u·2m⌋/2⌋ makes each pow2 level an exact pairwise row-fold of
  the level above. ONE dispatch at M = 2^⌈log₂ m_max⌉ (the Pallas MXU
  kernel on TPU), then log₂ cheap folds. A non-pow2 cap level is derived
  from the SAME dispatch by folding the M − m_max tail rows back onto the
  head (row j ≥ m_max dispatches to j − m_max): still one ±1 per column,
  so SᵀS = I exactly; the first M − m_max target rows are 2× likelier
  than the rest, which perturbs embedding constants only — and A is
  touched exactly once.
* ``srht`` — signs + a row-sample stream FIXED at m_max: one sign flip,
  one FWHT pass (the paper's O(n·d·log n) embedding; ``fwht_pallas`` on
  TPU, the jnp butterfly elsewhere) touching A once, then level-m = the
  first m sampled rows. Rows are i.i.d. uniform over the padded index
  space, so a prefix of the stream is a valid m-row sample for EVERY m —
  the same argument as the SJLT's ⌊u·m⌋. The 1/√m rescale folds into 1/m
  on the prefix-summed row-Grams, exactly as for the Gaussian.

Row weights (DESIGN.md §8): when the problem carries ``row_weights`` w
(the GLM Newton subproblem's Hessian weights), every family sketches
W^{1/2}A instead of A *inside the same single pass*: the Gaussian scales
its generated S tiles by w^{1/2} in-stream, the SJLT folds w^{1/2} into
its one-nonzero-per-column sign stream, and the SRHT folds w^{1/2} into
the sign flip that precedes the FWHT. No family materializes an (n, d)
weighted copy of A, and the one-touch ladder algebra is untouched — the
weight is a property of the sketch application, not of the ladder.

Compute dtype (DESIGN.md §10, ``kernels.precision``): every provider takes
``compute_dtype ∈ {"fp32", "bf16", "int8"}`` and applies it to the SKETCH
PASS only — bf16 operands with fp32 accumulation, or an int8-quantized A
stream whose per-row dequantization scales fold into the same per-row
scale slot the GLM weights use. The (L, B, d, d) level Grams this module
returns are always fp32: the ladder's Cholesky factors, guards, and δ̃
certificates downstream never see reduced precision.
"""

from __future__ import annotations

from typing import Protocol

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.gaussian_gram import gaussian_s_dense, resolve_stream
# COMPUTE_DTYPES is a deliberate re-export (launch/serve, examples,
# benchmarks all import it from here)
from repro.kernels.precision import COMPUTE_DTYPES, canonical_compute_dtype  # noqa: F401

from .quadratic import Quadratic


class LevelGramProvider(Protocol):
    """A sketch family's ladder algebra (see module docstring)."""

    name: str

    def sample(self, keys: jax.Array, m_max: int, n: int, dtype) -> dict:
        """Per-problem sketch randomness, one key per problem."""
        ...

    def level_grams(self, data: dict, q: Quadratic,
                    ladder: tuple[int, ...],
                    row_weights: jnp.ndarray | None = None,
                    compute_dtype: str | None = None) -> jnp.ndarray:
        """(L, B, d, d) fp32 Grams (S_m W^{1/2}A)ᵀ(S_m W^{1/2}A); touches A
        exactly once. ``row_weights`` (B, n) overrides ``q.row_weights``
        (defaulting to it); W = I when both are None. ``compute_dtype``
        selects the sketch pass's stream precision (module docstring);
        the returned Grams are fp32 in every mode."""
        ...


def _weights(q: Quadratic, row_weights) -> jnp.ndarray | None:
    return q.row_weights if row_weights is None else row_weights


def prefix_level_grams(R: jnp.ndarray, ladder: tuple[int, ...], *,
                       inv_m_scale: bool) -> jnp.ndarray:
    """(L, B, d, d) Grams from a (B, m_max, d) row stream whose level-m
    sketch is the first m rows: prefix-summed per-segment row-Grams, with
    the per-level 1/√m entry rescale folded in as 1/m when requested.
    A bf16 row stream (non-fp32 ``compute_dtype`` paths) accumulates into
    an fp32 Gram via ``preferred_element_type`` — the precision boundary
    of the whole dtype axis."""
    B, _, d = R.shape
    dtype = jnp.promote_types(R.dtype, jnp.float32)
    grams, acc, prev = [], jnp.zeros((B, d, d), dtype), 0
    for m in ladder:
        seg = R[:, prev:m, :]
        acc = acc + jnp.einsum("bmd,bme->bde", seg, seg,
                               preferred_element_type=dtype)
        grams.append(acc / jnp.asarray(m, dtype) if inv_m_scale else acc)
        prev = m
    return jnp.stack(grams)


def _uint32_seeds(keys: jax.Array) -> jnp.ndarray:
    """One uint32 counter-hash seed per problem key."""
    return jax.vmap(lambda k: jax.random.bits(k, dtype=jnp.uint32))(keys)


class GaussianStreamedProvider:
    """Streaming fused sketch→Gram (the default ``gaussian`` family)."""

    name = "gaussian"

    def sample(self, keys, m_max, n, dtype):
        return {"seeds": _uint32_seeds(keys)}

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        SA = ops.gaussian_sa(q.A, data["seeds"], ladder[-1],
                             row_weights=_weights(q, row_weights),
                             compute_dtype=compute_dtype)
        return prefix_level_grams(SA, ladder, inv_m_scale=True)


class GaussianDenseProvider:
    """Materialized-S baseline: identical sketch entries, O(B·m_max·n)."""

    name = "gaussian_dense"

    def sample(self, keys, m_max, n, dtype):
        return {"seeds": _uint32_seeds(keys)}

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        m_max = ladder[-1]
        B = data["seeds"].shape[0]
        # same per-row scale algebra as the streamed provider: w^{1/2} and
        # int8 dequantization scales merge into one (B, n) column scale on
        # the materialized S (fp32, applied before the contract-dtype cast)
        A, scale, ct, _ = resolve_stream(q.A, B, _weights(q, row_weights),
                                         compute_dtype)
        S = gaussian_s_dense(data["seeds"], m_max, q.n).astype(jnp.float32)
        if scale is not None:
            S = S * scale[:, None, :]
        if q.shared_A:
            SA = jnp.einsum("bmn,nd->bmd", S.astype(ct), A.astype(ct),
                            preferred_element_type=jnp.float32)
        else:
            SA = jnp.einsum("bmn,bnd->bmd", S.astype(ct), A.astype(ct),
                            preferred_element_type=jnp.float32)
        return prefix_level_grams(SA, ladder, inv_m_scale=True)


class SJLTProvider:
    """s=1 SJLT ladder: one dispatch at the top power of two, folds below."""

    name = "sjlt"

    def sample(self, keys, m_max, n, dtype):
        u = jax.vmap(lambda k: jax.random.uniform(
            jax.random.fold_in(k, 0), (n,), dtype))(keys)
        signs = jax.vmap(lambda k: jax.random.rademacher(
            jax.random.fold_in(k, 1), (n,), dtype))(keys)
        return {"u": u, "signs": signs}

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        u, signs = data["u"], data["signs"]
        m_max = ladder[-1]
        M = 1 << max(0, (m_max - 1).bit_length())   # top pow2 ≥ m_max
        rows = jnp.clip(
            jnp.floor(u * jnp.asarray(M, u.dtype)).astype(jnp.int32),
            0, M - 1)
        SA = ops.sjlt_apply_batched(                       # the ONE touch
            q.A, rows, signs, M, row_weights=_weights(q, row_weights),
            compute_dtype=compute_dtype)
        by_m = {M: SA}
        m = M
        while m > 1:                    # ⌊u·m⌋ = ⌊⌊u·2m⌋/2⌋: pairwise fold
            SA = SA[:, 0::2, :] + SA[:, 1::2, :]
            m //= 2
            by_m[m] = SA
        if m_max != M:                  # non-pow2 cap: fold the tail rows
            top = by_m[M]
            head, tail = top[:, :m_max, :], top[:, m_max:, :]
            by_m[m_max] = head + jnp.pad(
                tail, ((0, 0), (0, 2 * m_max - M), (0, 0)))
        return jnp.stack(
            [jnp.einsum("bmd,bme->bde", by_m[m], by_m[m]) for m in ladder])


class SRHTProvider:
    """SRHT ladder: one FWHT pass, level-m = first m of a fixed row stream.

    Row-sampling law: rows are i.i.d. uniform over the padded index space
    WITH replacement (``randint``) — a prefix of an i.i.d. stream is a
    valid m-row sample for EVERY ladder level, which is what makes the
    one-touch ladder work. ``kernels.ops.srht_sketch`` (the fixed-size
    sketch) instead samples WITHOUT replacement, the classical SRHT; both
    satisfy E[SᵀS] = I, and the laws agree in the sparse regime
    m ≪ n_pad where collisions are rare. Pinned by tests/test_sharded.py.
    """

    name = "srht"

    def sample(self, keys, m_max, n, dtype):
        n_pad = 1 << max(0, (n - 1).bit_length())
        signs = jax.vmap(lambda k: jax.random.rademacher(
            jax.random.fold_in(k, 0), (n,), dtype))(keys)
        rows = jax.vmap(lambda k: jax.random.randint(
            jax.random.fold_in(k, 1), (m_max,), 0, n_pad))(keys)
        return {"signs": signs, "rows": rows}

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        signs, rows = data["signs"], data["rows"]
        n, d = q.n, q.d
        B = signs.shape[0]
        n_pad = 1 << max(0, (n - 1).bit_length())
        w = _weights(q, row_weights)
        # signs (and, when weighted, w^{1/2}) fold into ONE per-row scale
        # fused into the FWHT kernel's VMEM tile — the sign-flipped /
        # weighted copy of A never round-trips HBM on the Pallas path
        scale = signs if w is None else signs * jnp.sqrt(w).astype(
            signs.dtype)
        A = q.A
        if (canonical_compute_dtype(compute_dtype) == "int8"
                and A.dtype != jnp.int8):
            # quantize before pad/broadcast so the padded copy is 1 B/elem;
            # dequantization scales join the fused per-row scale
            from repro.dist.compress import quantize_rows

            A, a_scales = quantize_rows(A)
            if q.shared_A:
                a_scales = jnp.broadcast_to(a_scales[None, :], (B, n))
            scale = scale * a_scales
        X = A if not q.shared_A else jnp.broadcast_to(
            A[None, :, :], (B, n, d))
        if n_pad != n:
            X = jnp.pad(X, ((0, 0), (0, n_pad - n), (0, 0)))
            scale = jnp.pad(scale, ((0, 0), (0, n_pad - n)))
        HX = ops.fwht_cols(X, row_scale=scale,             # the ONE touch
                           compute_dtype=compute_dtype)
        picked = jnp.take_along_axis(HX, rows[:, :, None], axis=1)
        return prefix_level_grams(picked, ladder, inv_m_scale=True)


class BlockEmulationProvider:
    """Single-device emulation of the sharded *concatenated* block sketch
    (DESIGN.md §5): shard k applies ``inner`` with ``fold_in(key, k)``
    randomness to rows [k·n/K, (k+1)·n/K) and the level Grams sum — the
    replicated reference for ``distributed.shard_level_grams`` (identical
    math, identical per-shard keys, no mesh), used by the multi-device
    tests and as the 1-device baseline in ``benchmarks/bench_sharded.py``.
    Pass the instance itself as the engine's ``sketch=``.

    ``drop_shards``: simulate shard dropout (DESIGN.md §9) — the listed
    shard indices contribute NOTHING to the level-Gram sum, exactly the
    K−1-block re-psum a pod performs after losing a data shard. The
    resulting Grams are still valid sketches of the SURVIVING rows, so the
    preconditioner is merely weaker, not wrong — unless the lost rows
    carried the dominant mass, in which case the engine's guards (stall
    detection → retry → fallback) are what keep the answer honest; the
    chaos suite (``tests/test_faults.py``) exercises both regimes."""

    def __init__(self, inner: "LevelGramProvider | str", n_shards: int,
                 drop_shards: tuple[int, ...] = ()):
        self.inner = get_provider(inner)
        self.n_shards = n_shards
        self.drop_shards = tuple(sorted(set(drop_shards)))
        if any(k < 0 or k >= n_shards for k in self.drop_shards):
            raise ValueError(
                f"drop_shards {drop_shards} out of range for {n_shards}")
        if len(self.drop_shards) >= n_shards:
            raise ValueError("cannot drop every shard")
        drop = (f"-drop{list(self.drop_shards)}" if self.drop_shards else "")
        self.name = f"block[{self.inner.name}x{n_shards}{drop}]"

    def _check(self, n: int) -> int:
        if n % self.n_shards:
            raise ValueError(
                f"n={n} not divisible by {self.n_shards} emulated shards")
        return n // self.n_shards

    def sample(self, keys, m_max, n, dtype):
        n_loc = self._check(n)
        return {"shards": [
            self.inner.sample(
                jax.vmap(lambda kb: jax.random.fold_in(kb, k))(keys),
                m_max, n_loc, dtype)
            for k in range(self.n_shards)
        ]}

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        n_loc = self._check(q.n)
        w = q.row_weights if row_weights is None else row_weights
        out = None
        for k, dk in enumerate(data["shards"]):
            if k in self.drop_shards:       # lost shard: absent from psum
                continue
            A_k = q.A[..., k * n_loc:(k + 1) * n_loc, :]
            w_k = None if w is None else w[:, k * n_loc:(k + 1) * n_loc]
            q_k = Quadratic(A=A_k, b=q.b, nu=q.nu, lam_diag=q.lam_diag,
                            batched=q.batched, row_weights=w_k)
            # per-shard reduced-precision pass; the (fp32) shard Grams sum
            # exactly — the emulated analogue of "bf16 passes, fp32 psum"
            g_k = self.inner.level_grams(dk, q_k, ladder,
                                         compute_dtype=compute_dtype)
            out = g_k if out is None else out + g_k
        return out


_PROVIDERS: dict[str, LevelGramProvider] = {
    p.name: p for p in (
        GaussianStreamedProvider(),
        GaussianDenseProvider(),
        SJLTProvider(),
        SRHTProvider(),
    )
}

PADDED_SKETCHES = tuple(_PROVIDERS)


def get_provider(sketch) -> LevelGramProvider:
    """Resolve a sketch-family name to its (stateless) provider; provider
    instances (e.g. a ``BlockEmulationProvider``) pass through unchanged."""
    if not isinstance(sketch, str):
        return sketch
    try:
        return _PROVIDERS[sketch]
    except KeyError:
        raise ValueError(
            f"padded engine supports {PADDED_SKETCHES}, got {sketch!r}"
        ) from None
