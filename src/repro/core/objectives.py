"""Regularized GLM objectives for the sketched-Newton layer (DESIGN.md §8).

The paper's solvers address the quadratic (1.1); its adaptive-sketch-size
machinery extends to regularized convex GLMs through the sketched Newton
step (Hessian sketch: Pilanci–Wainwright 2016; adaptive Newton sketch:
Lacotte–Wang–Pilanci 2021, arXiv:2105.07291). Every objective here is a
separable per-row loss plus the same ν²Λ ridge:

    F(x) = Σ_i ℓ(a_iᵀx, y_i) + ν²/2 · xᵀΛx ,

so the Newton system at x is exactly a *weighted* instance of (1.1):

    (AᵀW(x)A + ν²Λ) Δ = −∇F(x),   W(x) = diag(ℓ''(a_iᵀx, y_i)) ≥ 0 .

``GLMObjective`` packages the three per-row scalar maps (value, ℓ', ℓ'')
each family needs; everything acting on a batch of problems is derived
from them here with one margins pass t = Ax per evaluation. Families:

* ``logistic`` — y ∈ {0, 1}; ℓ = softplus(t) − y·t (stable via
  ``logaddexp``), ℓ' = σ(t) − y, ℓ'' = σ(t)(1 − σ(t)) ∈ (0, ¼].
* ``poisson``  — counts y ≥ 0, log link; ℓ = eᵗ − y·t, ℓ' = eᵗ − y,
  ℓ'' = eᵗ (margins are clipped at ``POISSON_CLIP`` so a wild line-search
  candidate cannot overflow f32 — the clip is far outside any sane
  operating range and is documented rather than hidden).
* ``huber``    — robust regression, residual r = t − y, threshold δ:
  ℓ = r²/2 for |r| ≤ δ else δ|r| − δ²/2; ℓ' = clip(r, ±δ),
  ℓ'' = 1{|r| ≤ δ} (the Newton weight simply drops outlier rows).
* ``quadratic``— ℓ = (t − y)²/2: W ≡ 1, one Newton step reproduces the
  ridge solve — the special case the rest of the repo is built on, kept
  as the consistency anchor between the GLM layer and the quadratic core.

ν²Λ ≻ 0 keeps every Newton system SPD even where ℓ'' vanishes (huber
outlier rows, saturated logistic margins) — the same reason the padded
engine's masked factorization stays SPD below d.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

POISSON_CLIP = 30.0     # e³⁰ ≈ 1e13: far beyond sane Poisson rates, finite


@dataclasses.dataclass(frozen=True)
class GLMObjective:
    """Per-row maps of a separable GLM loss ℓ(t, y) (t = aᵀx the margin).

    ``value``/``dloss``/``d2loss`` are elementwise (broadcasting) scalar
    maps; ``d2loss`` is the Newton Hessian weight w_i = ℓ''(t_i, y_i) that
    turns the Newton system into the weighted quadratic the sketch
    providers embed (``Quadratic.row_weights``)."""

    name: str
    value: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    dloss: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]
    d2loss: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray]


def _logistic_value(t, y):
    # softplus(t) − y·t, computed as logaddexp(0, t) for large-|t| stability
    return jnp.logaddexp(0.0, t) - y * t


def _logistic_d2(t, y):
    s = jax.nn.sigmoid(t)
    return s * (1.0 - s)


def _poisson_t(t):
    return jnp.clip(t, -POISSON_CLIP, POISSON_CLIP)


def _huber(delta: float) -> GLMObjective:
    def value(t, y):
        r = t - y
        a = jnp.abs(r)
        return jnp.where(a <= delta, 0.5 * r * r,
                         delta * a - 0.5 * delta * delta)

    def dloss(t, y):
        return jnp.clip(t - y, -delta, delta)

    def d2loss(t, y):
        return (jnp.abs(t - y) <= delta).astype(t.dtype)

    return GLMObjective(name=f"huber[{delta:g}]", value=value, dloss=dloss,
                        d2loss=d2loss)


OBJECTIVES: dict[str, GLMObjective] = {
    "logistic": GLMObjective(
        name="logistic",
        value=_logistic_value,
        dloss=lambda t, y: jax.nn.sigmoid(t) - y,
        d2loss=_logistic_d2,
    ),
    "poisson": GLMObjective(
        name="poisson",
        value=lambda t, y: jnp.exp(_poisson_t(t)) - y * t,
        dloss=lambda t, y: jnp.exp(_poisson_t(t)) - y,
        d2loss=lambda t, y: jnp.exp(_poisson_t(t)),
    ),
    "huber": _huber(1.0),
    "quadratic": GLMObjective(
        name="quadratic",
        value=lambda t, y: 0.5 * (t - y) ** 2,
        dloss=lambda t, y: t - y,
        d2loss=lambda t, y: jnp.ones_like(t),
    ),
}

GLM_FAMILIES = tuple(OBJECTIVES)


def get_objective(family: "GLMObjective | str") -> GLMObjective:
    """Resolve a family name ("huber:0.5" picks the δ); objective instances
    pass through unchanged."""
    if isinstance(family, GLMObjective):
        return family
    if family.startswith("huber:"):
        return _huber(float(family.split(":", 1)[1]))
    try:
        return OBJECTIVES[family]
    except KeyError:
        raise ValueError(
            f"GLM families are {GLM_FAMILIES} (or 'huber:<delta>'), "
            f"got {family!r}") from None


# ---------------------------------------------------------------------------
# Batched objective evaluations (one margins pass t = Ax each)
# ---------------------------------------------------------------------------

def margins(A: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """t = Ax, (B, n); A (B, n, d) per-problem or (n, d) shared."""
    if A.ndim == 2:
        return x @ A.T
    return jnp.einsum("bnd,bd->bn", A, x)


def glm_value(obj: GLMObjective, A, y, nu, lam_diag, x) -> jnp.ndarray:
    """F(x) − Σ_i ℓ(0, y_i) per problem, (B,): the loss is measured
    relative to x = 0. The per-row constant ℓ(0, y) cancels from every
    comparison the optimizer makes, but subtracting it matters in f32:
    all-zero padded rows (the serving path) contribute exactly 0 instead
    of n_pad·ℓ(0, 0), so the magnitude the line search must resolve is the
    actual loss decrease, not an O(n) constant that swamps its ulps."""
    t = margins(A, x)
    loss = jnp.sum(obj.value(t, y) - obj.value(jnp.zeros_like(t), y),
                   axis=-1)
    reg = 0.5 * (nu**2) * jnp.sum(lam_diag * x * x, axis=-1)
    return loss + reg


def synthetic_logistic_problem(key, n: int, d: int, *, scale: float = 1.0,
                               dtype=jnp.float32):
    """One synthetic logistic design: Gaussian A/√d and Bernoulli labels
    from planted coefficients (margins O(scale), so the Hessian weights
    vary across rows). The single data law shared by the tests, the
    quickstart, the serving demo and ``benchmarks/bench_newton.py``."""
    kA, kx, ky = jax.random.split(key, 3)
    A = jax.random.normal(kA, (n, d), dtype) / jnp.sqrt(
        jnp.asarray(d, dtype))
    p = jax.nn.sigmoid(A @ (scale * jax.random.normal(kx, (d,), dtype)))
    y = (jax.random.uniform(ky, (n,), dtype) < p).astype(dtype)
    return A, y


def synthetic_logistic_batch(key, B: int, n: int, d: int, *,
                             scale: float = 1.0, dtype=jnp.float32):
    """(A (B, n, d), y (B, n)) stacked from ``synthetic_logistic_problem``."""
    pairs = [synthetic_logistic_problem(k, n, d, scale=scale, dtype=dtype)
             for k in jax.random.split(key, B)]
    return (jnp.stack([a for a, _ in pairs]),
            jnp.stack([y for _, y in pairs]))


def glm_grad_and_weights(obj: GLMObjective, A, y, nu, lam_diag, x):
    """(∇F(x), W(x)) in one margins pass: ∇F = Aᵀℓ'(t, y) + ν²Λx (B, d),
    W = ℓ''(t, y) (B, n) — the Newton subproblem's ``row_weights``."""
    t = margins(A, x)
    g_row = obj.dloss(t, y)                              # (B, n)
    if A.ndim == 2:
        g = g_row @ A
    else:
        g = jnp.einsum("bnd,bn->bd", A, g_row)
    g = g + (nu**2)[:, None] * lam_diag * x
    return g, obj.d2loss(t, y)
