"""Adaptive sketched-Newton driver for regularized GLMs (DESIGN.md §8).

Outer loop: damped Newton with backtracking line search on

    F(x) = Σ_i ℓ(a_iᵀx, y_i) + ν²/2 · xᵀΛx      (``core.objectives``).

Inner loop: every Newton system (AᵀW(x_t)A + ν²Λ) Δ = −∇F(x_t) is a
*weighted* instance of the paper's quadratic (1.1), solved by the batched
padded adaptive engine (``core.adaptive_padded``) with the Hessian weights
W(x_t) riding through ``Quadratic.row_weights`` — the sketch providers
embed W^{1/2}A inside their one streaming pass over A, so each outer
iteration touches A exactly once for its sketch (plus the O(nd) margins /
gradient passes), never materializing a weighted copy.

Warm-started ladder (the adaptive-Newton-sketch idea, arXiv:2105.07291):
the per-problem doubling-ladder level found by outer step t seeds step
t+1's ``init_level`` — the effective dimension of AᵀW(x)A drifts slowly
along the Newton path, so re-climbing the ladder from m=1 each step would
waste the sketch sizes the controller already discovered. The sketch
itself is RE-SAMPLED each step (fold_in(key, t)): weights change, and a
fresh sketch keeps the δ̃ certificates honest.

Stopping is per-problem on the approximate Newton decrement
λ̃²/2 = −⟨∇F, Δ⟩/2 (the exact analogue of the quadratic core's δ̃ = (2.3));
each problem freezes once its decrement clears ``tol`` while the rest of
the batch keeps iterating inside the same fixed-shape executables.

The driver is a bounded host loop (≤ ``newton_iters``) over three jitted
pieces — gradient/weights, the padded engine, line search — all of whose
shapes are step-invariant, so every Newton step after the first reuses
compiled executables (the engine sees ``init_level`` as a traced array).
"""

from __future__ import annotations

import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .adaptive_padded import _is_single_key, padded_adaptive_solve_batched
from .objectives import (
    GLMObjective,
    get_objective,
    glm_grad_and_weights,
    glm_value,
)
from .quadratic import Quadratic, _as_batched_reg
from .status import SolveStatus


@partial(jax.jit, static_argnames=("obj",))
def _grad_and_weights(obj: GLMObjective, A, y, nu, lam, x):
    return glm_grad_and_weights(obj, A, y, nu, lam, x)


@partial(jax.jit, static_argnames=("obj", "backtracks", "c1"))
def _line_search(obj: GLMObjective, A, y, nu, lam, x, delta, dec, active,
                 *, backtracks: int, c1: float):
    """Per-problem backtracking Armijo: largest s ∈ {1, ½, …, 2^{1−K}} with
    F(x + sΔ) ≤ F(x) − c₁·s·λ̃². Returns (x⁺, s, made_progress); problems
    with no admissible step (or a non-descent Δ) keep x and report False —
    the driver freezes them rather than looping on a dead direction."""
    F0 = glm_value(obj, A, y, nu, lam, x)                     # (B,)
    ss = 0.5 ** jnp.arange(backtracks, dtype=F0.dtype)        # (K,)
    vals = jax.vmap(
        lambda s: glm_value(obj, A, y, nu, lam, x + s * delta))(ss)  # (K, B)
    # approximate Armijo: once the true decrease c₁sλ̃² falls below the
    # floating-point resolution of F itself, an exact comparison would
    # reject every candidate and stall the problem above tolerance — the
    # eps·(1+|F|) slack accepts steps whose descent f32 cannot resolve
    # (Newton's local contraction guarantees they still shrink λ̃²)
    slack = jnp.finfo(F0.dtype).eps * (1.0 + jnp.abs(F0))
    ok = (vals <= F0[None, :] - c1 * ss[:, None] * dec[None, :]
          + slack[None, :]) & jnp.isfinite(vals)
    any_ok = jnp.any(ok, axis=0) & (dec > 0)
    first = jnp.argmax(ok, axis=0)                 # first True (largest s)
    s = jnp.where(any_ok, ss[first], 0.0)
    move = (active & any_ok)[:, None]
    return jnp.where(move, x + s[:, None] * delta, x), s, any_ok


def adaptive_newton_solve_batched(
    family: GLMObjective | str,
    A: jnp.ndarray,
    y: jnp.ndarray,
    nu,
    *,
    lam_diag=None,
    keys: jax.Array | None = None,
    m_max: int,
    method: str = "pcg",
    sketch: str = "gaussian",
    newton_iters: int = 30,
    tol: float = 1e-10,
    inner_max_iters: int = 100,
    inner_tol: float = 1e-10,
    rho: float = 0.5,
    ls_backtracks: int = 12,
    ls_c1: float = 1e-4,
    mesh=None,
    compute_dtype: str = "fp32",
    deadline_s: float | None = None,
):
    """Solve a batch of B regularized GLM problems by adaptive sketched
    Newton. A (B, n, d) per-problem or (n, d) shared; y (B, n); ν scalar or
    (B,); Λ (d,) or (B, d). Returns (x, stats) with x (B, d) and

    * ``newton_iters``  (B,)  accepted outer steps per problem,
    * ``decrement``     (B,)  final λ̃²/2 (the Newton-level certificate),
    * ``converged``     (B,)  decrement ≤ tol (False = stalled/budget),
    * ``m_trajectory``  (T, B) inner m_final after each outer step,
    * ``m_final``       (B,)  last inner sketch size,
    * ``level``         (B,)  final ladder level (warm-start token),
    * ``inner_iters``   (B,)  total inner iterations across all steps.

    ``deadline_s``: wall-clock budget over the whole Newton solve, checked
    between OUTER steps (the natural segment boundary of the host-driven
    loop — the first step always runs). Problems still unfinished when the
    budget runs out keep their current iterate and its honest decrement
    and report ``DEADLINE_EXCEEDED`` (DESIGN.md §11).
    """
    y = jnp.asarray(y)
    if keys is None:
        keys = jax.random.PRNGKey(0)
    if _is_single_key(keys):
        keys = jax.random.split(keys, y.shape[0])

    def inner_solve(t, q_t, level):
        if mesh is not None:
            from .distributed import shard_quadratic

            q_t = shard_quadratic(q_t, mesh)
        step_keys = jax.vmap(lambda k: jax.random.fold_in(k, t))(keys)
        return padded_adaptive_solve_batched(
            q_t, step_keys, m_max=m_max, method=method, sketch=sketch,
            max_iters=inner_max_iters, rho=rho, tol=inner_tol, mesh=mesh,
            init_level=level, compute_dtype=compute_dtype)

    return _newton_loop(family, A, y, nu, lam_diag, inner_solve,
                        newton_iters=newton_iters, tol=tol,
                        ls_backtracks=ls_backtracks, c1=ls_c1,
                        deadline_s=deadline_s)


def _newton_loop(family, A, y, nu, lam_diag, inner_solve, *,
                 newton_iters: int, tol: float, ls_backtracks: int,
                 c1: float = 1e-4, deadline_s: float | None = None):
    """The shared damped-Newton outer loop (driver AND references — one
    copy of the stopping/line-search/freeze logic, so the baselines always
    validate the exact loop the driver runs). ``inner_solve(t, q_t, level)``
    produces the Newton step for the weighted subproblem ``q_t`` and either
    the padded engine's stats dict (driver) or None (references)."""
    obj = get_objective(family)
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    B = y.shape[0]
    d = A.shape[-1]
    nu_b, lam_b = _as_batched_reg(nu, lam_diag, B, d, A.dtype)

    x = jnp.zeros((B, d), A.dtype)
    level = jnp.zeros((B,), jnp.int32)
    done = jnp.zeros((B,), bool)
    dec = jnp.full((B,), jnp.inf, A.dtype)
    iters = jnp.zeros((B,), jnp.int32)
    inner_total = jnp.zeros((B,), jnp.int32)
    inner_status = jnp.zeros((B,), jnp.int32)   # last active inner verdict
    m_traj = []
    expired = jnp.zeros((B,), bool)
    t_start = time.perf_counter()

    for t in range(newton_iters):
        if (deadline_s is not None and t > 0
                and time.perf_counter() - t_start >= deadline_s):
            # budget spent between outer steps: unfinished problems keep
            # their current iterate + honest decrement, verdict below
            expired = ~done
            break
        g, w = _grad_and_weights(obj, A, y, nu_b, lam_b, x)
        q_t = Quadratic(A=A, b=-g, nu=nu_b, lam_diag=lam_b, batched=True,
                        row_weights=w)
        delta, s_in = inner_solve(t, q_t, level)
        # λ̃² = −⟨∇F, Δ⟩ (Δ solves the weighted system ≈ −H⁻¹∇F)
        dec_t = -jnp.sum(g * delta, axis=-1)
        newly_done = 0.5 * dec_t <= tol
        active = ~done & ~newly_done
        x, _, progressed = _line_search(
            obj, A, y, nu_b, lam_b, x, delta, dec_t, active,
            backtracks=ls_backtracks, c1=c1)
        if s_in is not None:
            # carry the discovered ladder level across steps (warm m_t)
            level = jnp.where(~done, s_in["level"], level)
            inner_total = inner_total + jnp.where(~done, s_in["iters"], 0)
            if "status" in s_in:
                inner_status = jnp.where(~done, s_in["status"], inner_status)
            m_traj.append(np.asarray(jnp.where(~done, s_in["m_final"], 0)))
        dec = jnp.where(~done, 0.5 * dec_t, dec)
        iters = iters + active.astype(jnp.int32)
        done = done | newly_done | (active & ~progressed)
        if bool(jnp.all(done)):
            break

    m_traj_arr = np.stack(m_traj) if m_traj else np.zeros((0, B), np.int32)
    m_last = np.zeros((B,), np.int32)
    for row in m_traj_arr:                     # last non-frozen m per problem
        m_last = np.where(row > 0, row, m_last)
    converged = dec <= tol
    # GLM verdict (DESIGN.md §9): convergence of the *outer* decrement is
    # what certifies the answer; a non-converged problem inherits its last
    # active inner engine failure (a poisoned/unusable Newton system is the
    # cause), and otherwise stalled — frozen by the line search or the
    # outer budget.
    engine_fail = (inner_status == jnp.int32(SolveStatus.LEVEL_INVALID)) | (
        inner_status == jnp.int32(SolveStatus.NAN_POISONED))
    status = jnp.where(
        converged, jnp.int32(SolveStatus.OK),
        jnp.where(expired, jnp.int32(SolveStatus.DEADLINE_EXCEEDED),
                  jnp.where(engine_fail, inner_status,
                            jnp.int32(SolveStatus.STALLED))))
    stats = {
        "newton_iters": iters,
        "decrement": dec,
        "converged": converged,
        "m_trajectory": m_traj_arr,
        "m_final": jnp.asarray(m_last),
        "level": level,
        "inner_iters": inner_total,
        "status": status,
        "stalled": status == jnp.int32(SolveStatus.STALLED),
    }
    return x, stats


def adaptive_newton_solve(family, A, y, nu, *, key=None, **kw):
    """Single-problem convenience: a B=1 batch through the batched driver;
    stats come back as scalars."""
    A = jnp.asarray(A)
    y = jnp.asarray(y)
    keys = None if key is None else (
        key[None] if _is_single_key(key) else key)
    x, stats = adaptive_newton_solve_batched(
        family, A, y[None, :], nu, keys=keys, **kw)
    out = {}
    for k, v in stats.items():
        if k == "m_trajectory":
            out[k] = v[:, 0]
        else:
            out[k] = v[0] if getattr(v, "ndim", 0) else v
    return x[0], out


def newton_cg_reference(family, A, y, nu, *, lam_diag=None,
                        newton_iters: int = 30, cg_iters: int = 200,
                        tol: float = 1e-10, ls_backtracks: int = 12):
    """Unpreconditioned Newton-CG baseline (benchmarks): the SAME outer
    loop, inner systems solved by plain CG on the weighted quadratic —
    what the GLM path costs WITHOUT sketched preconditioning."""
    from .solvers import cg_solve

    def inner_solve(t, q_t, level):
        delta, _ = cg_solve(q_t, jnp.zeros_like(q_t.b), iters=cg_iters)
        return delta, None

    x, _ = _newton_loop(family, A, y, nu, lam_diag, inner_solve,
                        newton_iters=newton_iters, tol=tol,
                        ls_backtracks=ls_backtracks)
    return x


def irls_reference(family, A, y, nu, *, lam_diag=None,
                   newton_iters: int = 50, tol: float = 1e-12):
    """Exact-Newton / IRLS reference (tests): the SAME outer loop, dense
    factorizations of the weighted Hessian via ``direct_solve``."""
    from .quadratic import direct_solve

    def inner_solve(t, q_t, level):
        return direct_solve(q_t), None

    x, _ = _newton_loop(family, A, y, nu, lam_diag, inner_solve,
                        newton_iters=newton_iters, tol=tol,
                        ls_backtracks=20)
    return x
