"""Preconditioned first-order methods (paper §1, §3).

All methods are instances of Definition 2.3:
    x_{t+1} ∈ x_0 + H_S⁻¹ · span{∇f(x_0), …, ∇f(x_t)} .

* IHS        — x⁺ = x − μ H_S⁻¹ ∇f(x), μ = 1−ρ; (ρ, ρ, 1)-linear (Thm 3.2).
* PCG        — optimal (Thm 3.3); (ρ, (1−√(1−ρ))/(1+√(1−ρ)), 4)-linear.
* Polyak-IHS — heavy-ball (Appendix A); asymptotically matches PCG.
* CG         — unpreconditioned baseline.

Each solver is expressed as an immutable state + a ``step`` function so the
adaptive controller (core/adaptive.py) can drive any of them, and as a
convenience ``run`` loop (lax.while_loop, fully jittable) for fixed sketches.

Every step also returns the approximate Newton decrement
δ̃ = ½ ∇fᵀ H_S⁻¹ ∇f (eq. 2.3), which is free given the preconditioner solve.

Batch polymorphism (DESIGN.md §6): when ``q.batched`` every state field
carries a leading problem axis and δ̃ / step sizes are per-problem (B,)
vectors — one compiled step advances B independent problems.
"""

from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from .precond import SketchedPrecond
from .quadratic import Quadratic, pdot, pscale


def rho_to_rate(method: str, rho: float) -> tuple[float, float]:
    """(φ(ρ), α) for Condition 2.4 per method."""
    if method == "ihs":
        return rho, 1.0
    if method in ("pcg", "polyak"):
        r = (1.0 - math.sqrt(1.0 - rho)) / (1.0 + math.sqrt(1.0 - rho))
        return r, 4.0
    raise ValueError(method)


def c_alpha_rho(alpha: float, rho: float) -> float:
    """c(α,ρ) = (1+√ρ)/(1−√ρ) · α (paper §1.1 notation)."""
    return (1.0 + math.sqrt(rho)) / (1.0 - math.sqrt(rho)) * alpha


# ---------------------------------------------------------------------------
# IHS
# ---------------------------------------------------------------------------

class IHSState(NamedTuple):
    x: jnp.ndarray
    grad: jnp.ndarray
    delta_tilde: jnp.ndarray  # δ̃ at x: scalar, or (B,) for batched problems


def ihs_init(q: Quadratic, P: SketchedPrecond, x0: jnp.ndarray) -> IHSState:
    g = q.grad(x0)
    return IHSState(x=x0, grad=g,
                    delta_tilde=0.5 * pdot(g, P.solve(g), q.batched))


def ihs_step(q: Quadratic, P: SketchedPrecond, st: IHSState, rho: float) -> IHSState:
    mu = 1.0 - rho
    x = st.x - mu * P.solve(st.grad)
    g = q.grad(x)
    return IHSState(x=x, grad=g,
                    delta_tilde=0.5 * pdot(g, P.solve(g), q.batched))


# ---------------------------------------------------------------------------
# Polyak-IHS (heavy-ball, Appendix A): μ_ρ = 2(1−ρ)/(1+√(1−ρ)),
# β_ρ = (1−√(1−ρ))/(1+√(1−ρ)).
# ---------------------------------------------------------------------------

class PolyakState(NamedTuple):
    x: jnp.ndarray
    x_prev: jnp.ndarray
    grad: jnp.ndarray
    delta_tilde: jnp.ndarray


def polyak_init(q: Quadratic, P: SketchedPrecond, x0: jnp.ndarray) -> PolyakState:
    g = q.grad(x0)
    return PolyakState(
        x=x0, x_prev=x0, grad=g,
        delta_tilde=0.5 * pdot(g, P.solve(g), q.batched)
    )


def polyak_step(
    q: Quadratic, P: SketchedPrecond, st: PolyakState, rho: float
) -> PolyakState:
    sq = math.sqrt(1.0 - rho)
    mu = 2.0 * (1.0 - rho) / (1.0 + sq)
    beta = (1.0 - sq) / (1.0 + sq)
    x = st.x - mu * P.solve(st.grad) + beta * (st.x - st.x_prev)
    g = q.grad(x)
    return PolyakState(
        x=x, x_prev=st.x, grad=g,
        delta_tilde=0.5 * pdot(g, P.solve(g), q.batched)
    )


# ---------------------------------------------------------------------------
# PCG (paper eq. 1.5 / Algorithm 4.2 inner loop)
# ---------------------------------------------------------------------------

class PCGState(NamedTuple):
    x: jnp.ndarray
    r: jnp.ndarray        # residual  b − Hx  (= −∇f)
    r_tilde: jnp.ndarray  # H_S⁻¹ r
    p: jnp.ndarray        # search direction
    delta_tilde: jnp.ndarray  # ½ rᵀ r̃  (δ̃ of eq. 2.3 up to the ½)


def pcg_init(q: Quadratic, P: SketchedPrecond, x0: jnp.ndarray) -> PCGState:
    r = q.b - q.hvp(x0)
    rt = P.solve(r)
    return PCGState(x=x0, r=r, r_tilde=rt, p=rt,
                    delta_tilde=0.5 * pdot(r, rt, q.batched))


def pcg_step(q: Quadratic, P: SketchedPrecond, st: PCGState, rho: float = 0.0
             ) -> PCGState:
    bt = q.batched
    Hp = q.hvp(st.p)
    denom = pdot(st.p, Hp, bt)
    # Guard: at exact convergence p → 0; keep alpha finite (per problem).
    alpha = jnp.where(denom > 0, 2.0 * st.delta_tilde / jnp.where(denom > 0, denom, 1.0), 0.0)
    x = st.x + pscale(alpha, bt) * st.p
    r = st.r - pscale(alpha, bt) * Hp
    rt = P.solve(r)
    dt_new = 0.5 * pdot(r, rt, bt)
    beta = jnp.where(st.delta_tilde > 0, dt_new / jnp.where(st.delta_tilde > 0, st.delta_tilde, 1.0), 0.0)
    p = rt + pscale(beta, bt) * st.p
    return PCGState(x=x, r=r, r_tilde=rt, p=p, delta_tilde=dt_new)


# ---------------------------------------------------------------------------
# Plain CG baseline (no preconditioner)
# ---------------------------------------------------------------------------

def cg_solve(q: Quadratic, x0: jnp.ndarray, iters: int, tol: float = 0.0):
    """Standard CG on Hx = b; returns (x, per-iteration ‖r‖² trace).

    Batched problems get per-problem α/β; the trace is (iters, B)."""
    bt = q.batched
    r0 = q.b - q.hvp(x0)

    def body(carry, _):
        x, r, p, rs = carry
        Hp = q.hvp(p)
        denom = pdot(p, Hp, bt)
        alpha = jnp.where(denom > 0, rs / jnp.where(denom > 0, denom, 1.0), 0.0)
        x = x + pscale(alpha, bt) * p
        r = r - pscale(alpha, bt) * Hp
        rs_new = pdot(r, r, bt)
        beta = jnp.where(rs > 0, rs_new / jnp.where(rs > 0, rs, 1.0), 0.0)
        p = r + pscale(beta, bt) * p
        return (x, r, p, rs_new), rs_new

    init = (x0, r0, r0, pdot(r0, r0, bt))
    (x, _, _, _), trace = jax.lax.scan(body, init, None, length=iters)
    return x, trace


# ---------------------------------------------------------------------------
# Generic fixed-sketch runner
# ---------------------------------------------------------------------------

METHODS = {
    "ihs": (ihs_init, ihs_step),
    "pcg": (pcg_init, pcg_step),
    "polyak": (polyak_init, polyak_step),
}


@partial(jax.jit, static_argnames=("method", "iters", "rho"))
def run_fixed(
    q: Quadratic,
    P: SketchedPrecond,
    x0: jnp.ndarray,
    *,
    method: str = "pcg",
    iters: int = 20,
    rho: float = 1.0 / 8.0,
):
    """Run ``iters`` steps with a fixed preconditioner; returns (x, δ̃-trace).

    Accepts batched (q, P, x0) — the trace is then (iters, B)."""
    init_fn, step_fn = METHODS[method]
    st = init_fn(q, P, x0)

    def body(st, _):
        st = step_fn(q, P, st, rho)
        return st, st.delta_tilde

    st, trace = jax.lax.scan(body, st, None, length=iters)
    return st.x, trace


# ---------------------------------------------------------------------------
# Newton / Gauss-Newton entry point (paper §1: "classical instances of
# Newton linear systems")
# ---------------------------------------------------------------------------

def newton_solve(J: jnp.ndarray, grad: jnp.ndarray, nu: float, *,
                 method: str = "pcg", sketch: str = "sjlt",
                 max_iters: int = 100, tol: float = 1e-10,
                 key: jax.Array | None = None):
    """Solve the (damped) Gauss-Newton system (JᵀJ + ν²I) δ = −grad with the
    adaptive sketching solver. J is the residual Jacobian / GN factor
    (n × d, e.g. from jax.jacfwd or stacked per-example JVPs); returns the
    Newton step δ."""
    from .adaptive import AdaptiveConfig, adaptive_solve
    from .quadratic import Quadratic

    d = J.shape[1]
    q = Quadratic(
        A=J, b=-grad, nu=jnp.asarray(nu, J.dtype),
        lam_diag=jnp.ones((d,), J.dtype),
    )
    res = adaptive_solve(
        q,
        AdaptiveConfig(method=method, sketch=sketch, max_iters=max_iters,
                       tol=tol),
        key=key,
    )
    return res.x, res
