"""Distributed-training utilities: gradient compression (EF-int8) and
sharding spec helpers live here; the solver-side distributed math is in
``repro.core.distributed``."""

from .compress import EFState, compress_decompress, compress_tree, init_ef
