"""Sharding specs for model params, decode caches, and input batches.

Conservative, shape-driven GSPMD placement: a tensor axis is sharded only
when its size is divisible by the target mesh axis — anything else is
replicated, so the same spec functions are valid on every mesh from the
1-device host mesh to the production pods (XLA inserts the collectives;
numerics match the single-device program up to reduction order).

Rules:
* params: 2-D+ weights shard their trailing axis over ``model`` when
  divisible (column-parallel matmuls — the all-gather-free layout for the
  transformer stack's GEMMs); with ``fsdp`` the first remaining divisible
  axis is additionally sharded over the data axes. 1-D tensors (norm
  scales, biases) replicate.
* caches: batch axis over the data axes, head axis over ``model`` when
  divisible.
* inputs: leading batch axis over the data axes.
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, PartitionSpec as P


def _data_axes(mesh: Mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a != "model")


def _axis_size(mesh: Mesh, axes) -> int:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return size


def _leaf_spec(leaf, mesh: Mesh, *, fsdp: bool) -> P:
    model = mesh.shape.get("model", 1)
    da = _data_axes(mesh)
    dsize = _axis_size(mesh, da)
    dims: list = [None] * leaf.ndim
    if leaf.ndim >= 2 and model > 1:
        for ax in reversed(range(leaf.ndim)):
            if leaf.shape[ax] % model == 0 and leaf.shape[ax] >= model:
                dims[ax] = "model"
                break
    if fsdp and leaf.ndim >= 2 and dsize > 1:
        for ax in range(leaf.ndim):
            if dims[ax] is None and leaf.shape[ax] % dsize == 0 \
                    and leaf.shape[ax] >= dsize:
                dims[ax] = da if len(da) > 1 else da[0]
                break
    return P(*dims)


def param_specs(cfg, params, mesh: Mesh, *, fsdp: bool = False):
    """PartitionSpec pytree matching ``params`` (see module docstring)."""
    return jax.tree.map(lambda l: _leaf_spec(l, mesh, fsdp=fsdp), params)


def cache_specs(cfg, cache, mesh: Mesh):
    """Decode-cache placement: batch over data axes, heads over model."""
    da = _data_axes(mesh)
    dsize = _axis_size(mesh, da)
    model = mesh.shape.get("model", 1)

    def spec(leaf) -> P:
        dims: list = [None] * leaf.ndim
        if leaf.ndim >= 1 and dsize > 1 and leaf.shape[0] % dsize == 0 \
                and leaf.shape[0] >= dsize:
            dims[0] = da if len(da) > 1 else da[0]
        if leaf.ndim >= 2 and model > 1 and leaf.shape[1] % model == 0 \
                and leaf.shape[1] >= model:
            dims[1] = "model"
        return P(*dims)

    return jax.tree.map(spec, cache)


def input_specs_for(batch, mesh: Mesh):
    """Input batches: leading (batch) axis over the data axes."""
    da = _data_axes(mesh)
    dsize = _axis_size(mesh, da)

    def spec(leaf) -> P:
        if leaf.ndim >= 1 and dsize > 1 and leaf.shape[0] % dsize == 0:
            return P(da if len(da) > 1 else da[0])
        return P()

    return jax.tree.map(spec, batch)
