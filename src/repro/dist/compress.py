"""Error-feedback int8 gradient compression (EF-SGD style).

Cross-replica gradient all-reduces dominate data-parallel step time at
pod scale; 4× compression (f32 → int8) with error feedback keeps the
convergence of uncompressed SGD on smooth objectives: the residual of each
quantization is carried over and added to the next gradient before
compressing, so the *accumulated* transmitted signal is unbiased up to a
bounded lag (Karimireddy et al., "Error Feedback Fixes SignSGD").

Quantization is symmetric per-tensor int8: scale = max|v|/127, code =
round(v/scale) ∈ [−127, 127]. The decompressed tensor is what the step
consumes; ``EFState.residual`` holds v − decompress(compress(v)).

Everything is jit-compatible (pure functions over pytrees).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    residual: jax.Array | dict | tuple  # pytree matching the gradients


def init_ef(grads) -> EFState:
    """Zero error-feedback state shaped like the gradient pytree."""
    return EFState(residual=jax.tree.map(jnp.zeros_like, grads))


def _quantize(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8: returns (codes int8, scale f32)."""
    scale = jnp.max(jnp.abs(v)) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(v / safe), -127, 127).astype(jnp.int8)
    return codes, scale


def _dequantize(codes: jnp.ndarray, scale: jnp.ndarray,
                dtype=jnp.float32) -> jnp.ndarray:
    return codes.astype(dtype) * scale


def quantize_rows(v: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-ROW symmetric int8 over the last axis: v (…, n, d) →
    (codes int8 (…, n, d), scales f32 (…, n)) with
    v̂ = scales[…, None]·codes and |v̂ − v| ≤ scales/2 entrywise.

    The row granularity is what the mixed-precision sketch passes need
    (``kernels.precision``): every sketch family owns a per-row scale slot
    (GLM w^{1/2} folding), so diag(scales) folds there and dequantization
    happens in-register on the streamed codes — never as a float copy of
    v. All-zero rows get scale 0 with a safe divisor (codes 0), matching
    ``_quantize``'s convention."""
    scale = jnp.max(jnp.abs(v), axis=-1) / 127.0
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(v / safe[..., None]), -127, 127).astype(
        jnp.int8)
    return codes, scale.astype(jnp.float32)


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Materialized Â = diag(scales)·codes — the dense oracle the in-
    register kernels must match exactly (tests/test_mixed_precision.py)."""
    return codes.astype(dtype) * scales[..., None].astype(dtype)


def compress_decompress(v: jnp.ndarray, residual: jnp.ndarray
                        ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One EF-int8 round for a single tensor.

    Returns (v_hat, new_residual): v_hat = Q(v + residual) is what the wire
    carries (int8 codes + one scale — materialized back to v's dtype here),
    new_residual = (v + residual) − v_hat is held locally for the next step.
    """
    target = v + residual
    codes, scale = _quantize(target)
    v_hat = _dequantize(codes, scale, v.dtype)
    return v_hat, target - v_hat


def compress_tree(grads, ef: EFState) -> tuple[jax.Array | dict, EFState]:
    """EF-int8 over a gradient pytree; returns (decompressed grads, state)."""
    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(ef.residual)
    out = [compress_decompress(g, r) for g, r in zip(flat_g, flat_r)]
    g_hat = treedef.unflatten([o[0] for o in out])
    new_res = treedef.unflatten([o[1] for o in out])
    return g_hat, EFState(residual=new_res)


def compression_ratio(grads) -> float:
    """Wire-bytes ratio vs f32 (int8 codes + one f32 scale per tensor)."""
    leaves = jax.tree.leaves(grads)
    raw = sum(4 * l.size for l in leaves)
    compressed = sum(l.size + 4 for l in leaves)
    return raw / compressed
