"""Collective-communication accounting — delegation onto the audit engine.

``cost_analysis()`` does not report collective traffic, so the roofline's
collective term comes from summing operand sizes of every all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute in
``compiled.as_text()``. The parser — plus the buffer-donation scanner the
retrace audit uses on the same HLO text — lives in
:mod:`repro.analysis.audit.hlo_utils`; this module keeps the historical
import surface.
"""

from __future__ import annotations

from .audit.hlo_utils import (  # noqa: F401
    COLLECTIVE_OPS,
    collective_bytes_from_hlo,
    donated_input_indices,
)
