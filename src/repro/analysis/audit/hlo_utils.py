"""Optimized-HLO text scans: collective traffic and donation markers.

The canonical home of the collective-bytes parser (``cost_analysis()``
does not report collective traffic); ``repro.analysis.collectives`` is a
compatibility shim over this module. The donation scan reads the lowering
of a jitted entry point and reports which inputs carry buffer-donation /
aliasing annotations — how the retrace-sentinel rule proves the 20-field
``PaddedState`` is donated across segment re-dispatch instead of doubling
the engine's state footprint every segment.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute", "collective-broadcast",
)

# e.g.  %ag = bf16[4,128,256]{2,1,0} all-gather(...)
_LINE_RE = re.compile(
    r"=\s*(?:\([^)]*\)\s*)?((?:\w+\[[0-9,]*\][^ ]*\s*,?\s*)+)\s*"
    r"(" + "|".join(COLLECTIVE_OPS) + r")(?:-start|-done)?\("
)
_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes_from_hlo(hlo_text: str) -> dict:
    """Returns {'total_bytes', 'by_op': {op: {'bytes', 'count'}}} where bytes
    is the summed *output* operand size of each collective instruction
    (counting -start once, ignoring -done duplicates)."""
    by_op: dict = defaultdict(lambda: {"bytes": 0, "count": 0})
    for line in hlo_text.splitlines():
        s = line.strip()
        if not any(op in s for op in COLLECTIVE_OPS):
            continue
        if "-done(" in s or "-done.1(" in s:
            continue  # counted at -start
        m = _LINE_RE.search(s)
        if not m:
            continue
        shapes_str, op = m.group(1), m.group(2)
        nbytes = sum(
            _shape_bytes(dt, dims) for dt, dims in _SHAPE_RE.findall(shapes_str)
        )
        by_op[op]["bytes"] += nbytes
        by_op[op]["count"] += 1
    total = sum(v["bytes"] for v in by_op.values())
    return {"total_bytes": total, "by_op": dict(by_op)}


# StableHLO spells input donation either as the modern jax.buffer_donor
# attribute or as an input/output aliasing pair; match both so the check
# survives jaxlib bumps.
_DONOR_RE = re.compile(r"%arg(\d+)[^\n{]*\{[^}]*jax\.buffer_donor[^}]*\}")
_ALIAS_RE = re.compile(r"%arg(\d+)[^\n{]*\{[^}]*tf\.aliasing_output[^}]*\}")


def donated_input_indices(stablehlo_text: str) -> set[int]:
    """Flat input indices carrying a donation/aliasing annotation in a
    lowered module's text (``fn.lower(...).as_text()``)."""
    out: set[int] = set()
    for rx in (_DONOR_RE, _ALIAS_RE):
        out.update(int(m.group(1)) for m in rx.finditer(stablehlo_text))
    return out
