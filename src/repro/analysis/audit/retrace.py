"""Behavioral checks: retrace sentinel + state-donation audit.

These are the two invariants a jaxpr cannot show. The retrace sentinel
EXECUTES each jitted entry point twice on tiny problems — the second call
with fresh same-shaped dynamic arguments — and asserts the compilation
cache did not grow: a new trace on shape-identical inputs means a dynamic
value leaked into a static argument (one silent recompile per service
request, the classic serving perf cliff). The donation audit lowers the
segment executable and checks the 20-field ``PaddedState`` carries
buffer-donation/aliasing markers: the host driver re-dispatches that
executable every ``segment_trips`` loop trips, and an undonated state
doubles the engine's state footprint on every dispatch.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .hlo_utils import donated_input_indices
from .rules import Violation

# tiny but structurally faithful: batched, non-pow2 n, real ladder
_B, _N, _D, _M = 2, 48, 6, 8


def _problem(seed: int):
    from repro.core.quadratic import from_least_squares_batch

    key = jax.random.PRNGKey(seed)
    A = jax.random.normal(key, (_B, _N, _D), jnp.float32)
    y = jax.random.normal(jax.random.fold_in(key, 1), (_B, _N), jnp.float32)
    q = from_least_squares_batch(A, y, jnp.asarray([0.1, 0.2]))
    return q, jax.random.split(jax.random.fold_in(key, 2), _B)


def _cache_size(fn) -> int | None:
    get = getattr(fn, "_cache_size", None)
    return get() if callable(get) else None


def check_retrace_sentinel() -> list[Violation]:
    """Zero new traces when an entry point is re-dispatched with fresh
    same-shape dynamic args, across the whole segmented lifecycle."""
    from repro.core.adaptive_padded import (
        finalize_padded_solve,
        padded_adaptive_solve_batched,
        padded_solve_segment,
        prepare_padded_solve,
        reprecondition_padded,
    )

    out: list[Violation] = []

    def run_cycle(seed: int):
        q, keys = _problem(seed)
        pre, st = prepare_padded_solve(q, keys, m_max=_M, sketch="gaussian")
        st = padded_solve_segment(q, pre, st, jnp.int32(4), method="pcg")
        grams = jnp.broadcast_to(
            jnp.eye(_D, dtype=jnp.float32),
            (pre.pinvs.shape[0], _B, _D, _D))
        pre2, st = reprecondition_padded(q, pre, st, grams)
        x, stats = finalize_padded_solve(pre2, st, m_max=_M)
        x2, _ = padded_adaptive_solve_batched(q, keys, m_max=_M,
                                              method="pcg")
        return jax.block_until_ready((x, x2))

    tracked = {
        "prepare_padded_solve": prepare_padded_solve,
        "padded_solve_segment": padded_solve_segment,
        "finalize_padded_solve": finalize_padded_solve,
        "reprecondition_padded": reprecondition_padded,
        "padded_adaptive_solve_batched": padded_adaptive_solve_batched,
    }
    run_cycle(0)  # populate the caches
    before = {name: _cache_size(fn) for name, fn in tracked.items()}
    run_cycle(1)  # fresh data, identical shapes/statics
    for name, fn in tracked.items():
        after = _cache_size(fn)
        if before[name] is None or after is None:
            continue  # cache introspection unavailable on this jax
        if after != before[name]:
            out.append(Violation(
                "retrace_sentinel", name,
                f"re-dispatch with fresh same-shape args grew the "
                f"compilation cache {before[name]} → {after} (a dynamic "
                f"value is flowing into a static argument)"))
    return out


def check_state_donation() -> list[Violation]:
    """The segment executable must donate (alias) every ``PaddedState``
    leaf — and nothing else — across re-dispatch."""
    from repro.core.adaptive_padded import (
        padded_solve_segment,
        prepare_padded_solve,
    )

    q, keys = _problem(0)
    pre, st = jax.eval_shape(
        lambda q, k: prepare_padded_solve(q, k, m_max=_M), q, keys)
    lowered = padded_solve_segment.lower(q, pre, st, jnp.int32(4),
                                         method="pcg")
    donated = donated_input_indices(lowered.as_text())
    n_state = len(jax.tree_util.tree_leaves(st))
    out: list[Violation] = []
    if len(donated) != n_state:
        out.append(Violation(
            "retrace_sentinel", "padded_solve_segment",
            f"{len(donated)} of the {n_state} PaddedState leaves are "
            f"donated across segment re-dispatch (every state field must "
            f"alias its output buffer)"))
    return out


def run_behavioral_checks() -> list[Violation]:
    return check_retrace_sentinel() + check_state_donation()
