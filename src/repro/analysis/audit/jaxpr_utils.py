"""The one jaxpr walker every invariant check shares.

Everything here is pure introspection on a ``ClosedJaxpr``: recursion into
sub-jaxprs (scan/while/cond/pjit/shard_map bodies), primitive inventory
with the *context path* each equation sits under (so a rule can ask "is
this psum inside a while_loop body?"), intermediate-aval enumeration for
the memory claims, and source provenance for actionable violation
messages. ``repro.analysis.memscan`` and the tier-1 jaxpr-scan tests are
thin delegations onto this module — the scans used to be copy-pasted per
test file, which meant a new entry point shipped unaudited by default.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Iterator

import jax
import numpy as np


def subjaxprs(eqn) -> Iterable:
    """Every sub-jaxpr referenced by an equation's params (scan/while/cond
    bodies, pjit calls, shard_map, custom_* wrappers)."""
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jax.core.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, jax.core.Jaxpr):
                    yield item


@dataclasses.dataclass(frozen=True)
class EqnSite:
    """An equation plus where it sits: ``path`` is the tuple of enclosing
    primitive names from the root, e.g. ``("pjit", "while")`` for an
    equation inside the engine loop body."""

    eqn: object
    path: tuple[str, ...]

    @property
    def in_while_body(self) -> bool:
        return "while" in self.path

    @property
    def primitive(self) -> str:
        return self.eqn.primitive.name


def iter_eqns(closed_jaxpr) -> Iterator[EqnSite]:
    """Yield every equation (recursively) with its enclosing-primitive
    path. Duplicate sub-jaxpr objects are visited once."""
    seen: set[int] = set()

    def walk(jx, path):
        if id(jx) in seen:
            return
        seen.add(id(jx))
        for eqn in jx.eqns:
            yield EqnSite(eqn, path)
            sub_path = path + (eqn.primitive.name,)
            for sub in subjaxprs(eqn):
                yield from walk(sub, sub_path)

    yield from walk(closed_jaxpr.jaxpr, ())


def collect_eqns(closed_jaxpr, primitive: str | tuple[str, ...]) -> list:
    """All equations (recursively) whose primitive name matches. The
    canonical replacement for the per-test ``psum_eqns`` walkers."""
    names = (primitive,) if isinstance(primitive, str) else tuple(primitive)
    return [s.eqn for s in iter_eqns(closed_jaxpr) if s.primitive in names]


def collect_sites(closed_jaxpr,
                  primitive: str | tuple[str, ...]) -> list[EqnSite]:
    """Like :func:`collect_eqns` but keeps the context path."""
    names = (primitive,) if isinstance(primitive, str) else tuple(primitive)
    return [s for s in iter_eqns(closed_jaxpr) if s.primitive in names]


def count_primitive(closed_jaxpr, primitive: str | tuple[str, ...]) -> int:
    """Recursive occurrence count of a primitive (e.g. one ``scatter-add``
    per SJLT dispatch — the one-touch cap-level claim)."""
    return len(collect_eqns(closed_jaxpr, primitive))


def while_body_jaxprs(closed_jaxpr) -> list:
    """The body jaxprs of every while_loop in the program (the engine's
    adaptive loop; collectives are forbidden inside)."""
    out = []
    for site in iter_eqns(closed_jaxpr):
        if site.primitive == "while":
            body = site.eqn.params.get("body_jaxpr")
            if body is not None:
                out.append(body)
    return out


def iter_intermediate_avals(closed_jaxpr) -> Iterable:
    """Yield the aval of every equation output, recursively."""
    for site in iter_eqns(closed_jaxpr):
        for var in site.eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                yield aval


def aval_bytes(aval) -> int:
    return int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize


def max_intermediate_bytes(closed_jaxpr) -> tuple[int, tuple[int, ...]]:
    """(bytes, shape) of the single largest intermediate array produced
    anywhere in the program (sub-jaxprs included)."""
    best, best_shape = 0, ()
    for aval in iter_intermediate_avals(closed_jaxpr):
        nbytes = aval_bytes(aval)
        if nbytes > best:
            best, best_shape = nbytes, tuple(aval.shape)
    return best, best_shape


def has_intermediate_of_shape(closed_jaxpr, shape: tuple[int, ...],
                              dtype=None) -> bool:
    """True if any intermediate anywhere has exactly this shape (and, when
    given, this dtype)."""
    shape = tuple(shape)
    for a in iter_intermediate_avals(closed_jaxpr):
        if tuple(a.shape) != shape:
            continue
        if dtype is None or a.dtype == np.dtype(dtype):
            return True
    return False


def find_intermediates(closed_jaxpr,
                       pred: Callable[[object], bool]) -> list[EqnSite]:
    """Equation sites with at least one output aval satisfying ``pred`` —
    the one-touch / precision rules' workhorse (keeps provenance)."""
    out = []
    for site in iter_eqns(closed_jaxpr):
        for var in site.eqn.outvars:
            aval = getattr(var, "aval", None)
            if aval is not None and hasattr(aval, "shape") and pred(aval):
                out.append(site)
                break
    return out


# Pure data movement: consuming A through these is a re-index of the same
# touch, not a second pass over the data.
_DATA_MOVEMENT_PRIMS = frozenset({
    "convert_element_type", "reshape", "transpose", "broadcast_in_dim",
    "slice", "dynamic_slice", "dynamic_update_slice", "squeeze", "pad",
    "concatenate", "rev", "gather", "copy", "device_put", "stop_gradient",
    "select_n",
})


def count_a_consumers(closed_jaxpr, n: int, d: int) -> int:
    """Number of COMPUTE equations consuming an A-shaped operand — an
    operand whose trailing dims are (≥n_rows, d) for any row count ≥ n
    (covers both full A and row-sharded/padded variants; n-CHUNKED slices
    of A are excluded on purpose: the chunks of one streaming pass are one
    touch, and they enter through a `slice`, which is data movement).

    Containers (pjit/while/scan/...) are not consumers themselves — their
    bodies are walked instead, and walked PER OCCURRENCE (no sub-jaxpr
    dedup: jit caching makes P identical solve dispatches share one body
    object, and deduping them would hide P−1 passes over A). The count is
    calibration-relative: the one-touch rule compares a composed λ-grid
    graph against its single-point reference rather than asserting an
    absolute number."""

    def _is_a(aval) -> bool:
        shp = tuple(getattr(aval, "shape", ()))
        return len(shp) >= 2 and shp[-1] == d and shp[-2] >= n

    def walk(jx) -> int:
        c = 0
        for eqn in jx.eqns:
            subs = list(subjaxprs(eqn))
            if subs:
                for sub in subs:
                    c += walk(sub)
                continue
            if eqn.primitive.name in _DATA_MOVEMENT_PRIMS:
                continue
            for v in eqn.invars:
                aval = getattr(v, "aval", None)
                if aval is not None and _is_a(aval):
                    c += 1
                    break
        return c

    return walk(closed_jaxpr.jaxpr)


def eqn_provenance(eqn) -> str:
    """``file:line (primitive)`` for the user frame that created an
    equation — what makes a violation actionable."""
    name = getattr(getattr(eqn, "primitive", None), "name", "?")
    src = getattr(eqn, "source_info", None)
    try:
        from jax._src import source_info_util

        frame = source_info_util.user_frame(src)
        if frame is not None:
            return f"{frame.file_name}:{frame.start_line} ({name})"
    except Exception:  # provenance is best-effort across jax versions
        pass
    return f"<no source> ({name})"


def jaxpr_text(closed_jaxpr) -> str:
    """Stable pretty-print, for equation-identity comparisons (the
    ``compute_dtype="fp32" == pre-axis graph`` claim) and primitive-name
    greps that have no structured accessor."""
    return str(closed_jaxpr)
