"""Deliberately-violating graphs: every rule's negative control.

Each fixture builds an :class:`EntryPoint` whose graph breaks exactly the
invariant its name says — a dense sketch parked in HBM, a second psum, a
bf16 Cholesky, a reused key literal, a value-leaking static argument. The
audit suite (``tests/test_audit.py``) runs the real rules against these
and asserts they FAIL with the right provenance: a rule that cannot catch
its own seeded violation is a rubber stamp, not a gate.

This module is excluded from the source lints (``ast_rules.lint_tree``
skips ``fixtures.py``) because existing to violate is its job.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .entrypoints import EntryPoint, _sds

# Big enough that the chunk-aware one-touch allowances do NOT excuse the
# violation: n must exceed the 2048-column stream chunk.
_B, _N, _D, _M = 2, 4096, 8, 64


# ---------------------------------------------------------------------------
# one_touch violations
# ---------------------------------------------------------------------------

def dense_sketch_ep() -> EntryPoint:
    """A 'gaussian' pass that materializes the full (B, m_max, n) sketch —
    the exact HBM blow-up the streamed pass exists to avoid."""

    def build():
        def fn(A, key):
            S = jax.random.normal(key, (_B, _M, _N), jnp.float32)
            SA = jnp.einsum("bmn,bnd->bmd", S, A)
            return jnp.einsum("bmd,bme->bde", SA, SA)

        return jax.make_jaxpr(fn)(_sds((_B, _N, _D)),
                                  jax.random.PRNGKey(0))

    return EntryPoint(
        name="fixture:dense_sketch", kind="provider", build=build,
        meta={"family": "gaussian", "compute_dtype": "fp32",
              "B": _B, "n": _N, "d": _D, "m_max": _M})


def a_copy_ep() -> EntryPoint:
    """A 'gaussian' pass that takes a second, full-size fp32 touch of A
    (the sign-flipped copy the families promise to fuse)."""

    def build():
        def fn(A, w):
            Aw = A * w[:, :, None]          # fp32 (B, n, d) second touch
            return jnp.einsum("bnd,bne->bde", Aw, Aw)

        return jax.make_jaxpr(fn)(_sds((_B, _N, _D)), _sds((_B, _N)))

    return EntryPoint(
        name="fixture:a_copy", kind="provider", build=build,
        meta={"family": "gaussian", "compute_dtype": "fp32",
              "B": _B, "n": _N, "d": _D, "m_max": _M})


# ---------------------------------------------------------------------------
# collective_inventory violations
# ---------------------------------------------------------------------------

def double_psum_ep() -> EntryPoint:
    """A sharded precompute that psums TWICE (partial Grams, then again
    'for safety') — double the collective bytes of the documented one."""

    def build():
        from repro.core.distributed import _smap

        mesh = jax.make_mesh((1,), ("data",))

        def local(A):
            G = jnp.einsum("bnd,bne->bde", A, A)
            G = jax.lax.psum(G, axis_name="data")
            return jax.lax.psum(G, axis_name="data")

        fn = _smap(local, mesh, in_specs=(P(None, "data", None),),
                   out_specs=P())
        return jax.make_jaxpr(fn)(_sds((_B, _N, _D)))

    return EntryPoint(
        name="fixture:double_psum", kind="sharded", build=build,
        meta={"family": "gaussian", "compute_dtype": "fp32",
              "psum_budget": 1, "B": _B, "n": _N, "d": _D, "m_max": _M})


def loop_collective_ep() -> EntryPoint:
    """A psum INSIDE the adaptive while_loop body — one collective per
    iteration instead of one per solve."""

    def build():
        from repro.core.distributed import _smap

        mesh = jax.make_mesh((1,), ("data",))

        def local(A):
            g0 = jnp.einsum("bnd,bne->bde", A, A)

            def body(carry):
                i, g = carry
                return i + 1, jax.lax.psum(g, axis_name="data")

            _, g = jax.lax.while_loop(lambda c: c[0] < 3, body,
                                      (jnp.int32(0), g0))
            return g

        fn = _smap(local, mesh, in_specs=(P(None, "data", None),),
                   out_specs=P())
        return jax.make_jaxpr(fn)(_sds((_B, _N, _D)))

    return EntryPoint(
        name="fixture:loop_collective", kind="sharded", build=build,
        meta={"family": "gaussian", "compute_dtype": "fp32",
              "psum_budget": 1, "B": _B, "n": _N, "d": _D, "m_max": _M})


# ---------------------------------------------------------------------------
# precision_boundary violations
# ---------------------------------------------------------------------------

def bf16_cholesky_ep() -> EntryPoint:
    """A bf16 pipeline that forgets the fp32 promotion: the Gram is
    accumulated in bf16, factorized in bf16, and a bf16 residual is
    carried through the iteration loop."""

    def build():
        def fn(A):
            Ah = A.astype(jnp.bfloat16)
            G = jax.lax.dot_general(                    # bf16 accumulate
                Ah, Ah, (((1,), (1,)), ((0,), (0,))))
            G = G + 1e-3 * jnp.eye(_D, dtype=jnp.bfloat16)
            L = jax.lax.linalg.cholesky(G)              # bf16 factorization

            def body(carry):
                i, r = carry                            # bf16 loop carry
                return i + 1, r * jnp.bfloat16(0.5)

            _, r = jax.lax.while_loop(
                lambda c: c[0] < 4, body,
                (jnp.int32(0), jnp.zeros((_B, _D), jnp.bfloat16)))
            return L, r

        return jax.make_jaxpr(fn)(_sds((_B, _N, _D)))

    return EntryPoint(
        name="fixture:bf16_cholesky", kind="provider", build=build,
        meta={"family": "gaussian", "compute_dtype": "bf16",
              "B": _B, "n": _N, "d": _D, "m_max": _M})


# ---------------------------------------------------------------------------
# retrace_sentinel violations
# ---------------------------------------------------------------------------

def make_leaky_static_fn():
    """A jitted solve that routes a per-request VALUE (the regularizer)
    through a static argument: every fresh request compiles a fresh
    executable — the exact cliff the retrace sentinel exists to catch."""
    from functools import partial

    @partial(jax.jit, static_argnames=("nu",))
    def leaky_solve(x, nu):
        return x / (1.0 + nu)

    return leaky_solve


def make_undonated_segment_fn():
    """A segment-shaped executable whose state is NOT donated: the 20-leaf
    analogue is ``padded_solve_segment`` before buffer donation landed."""

    @jax.jit
    def undonated_segment(q, st):
        return jax.tree_util.tree_map(lambda a: a + q, st)

    return undonated_segment


# ---------------------------------------------------------------------------
# key_hygiene / status_lattice violating SOURCE (strings, so the tree lint
# over real modules never sees them)
# ---------------------------------------------------------------------------

REUSED_ROOT_KEY_SRC = """
import jax

def sketch_a():
    return jax.random.PRNGKey(42)

def sketch_b():
    return jax.random.PRNGKey(42)
"""

REUSED_FOLD_IN_SRC = """
import jax

def derive(key):
    ka = jax.random.fold_in(key, 7)
    kb = jax.random.fold_in(key, 7)
    return ka, kb
"""

BARE_STATUS_SRC = """
def converged(stats):
    return stats["status"] == 0
"""

CLEAN_STATUS_SRC = """
from repro.core.adaptive_padded import SolveStatus

def converged(stats):
    return stats["status"] == SolveStatus.CONVERGED
"""

ALL_FIXTURES = (dense_sketch_ep, a_copy_ep, double_psum_ep,
                loop_collective_ep, bf16_cholesky_ep)
