"""The declarative invariant rules (DESIGN.md §12).

Every rule sees one traced entry point (an :class:`EntryPoint` plus its
``ClosedJaxpr``) and returns the violations it finds — empty means the
invariant holds for that graph. Rules are registered in ``RULES`` and the
runner applies every applicable rule to every entry point, so a new
provider family / method / shape class is audited the moment it exists.

The allowances are the DOCUMENTED exceptions, not escape hatches:

* ``gaussian_dense`` is the materialized-S memory baseline — (B, m_max, n)
  is its entire point.
* ``sjlt`` on the jnp reference backend materializes the sign-scaled
  stream copy of A before its one segment-sum dispatch (the Pallas path
  fuses it into the kernel's VMEM tile); the copy is A-sized, not
  sketch-sized, so the O(B·m_max·n) claim is untouched.
* ``srht`` peaks at the (B, n_pad, d) FWHT stack — the transform is
  in-place in the padded index space by construction.
* ``int8`` mode quantizes A per row first; the |A| pass that computes the
  dequantization scales is fp32 and A-shaped.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from . import jaxpr_utils as ju

REDUCED_FLOAT = ("bfloat16", "float16")
COLLECTIVE_PRIMS = (
    "psum", "psum2", "all_gather", "all_to_all", "ppermute", "pmax", "pmin",
    "reduce_scatter", "pgather",
)
FACTORIZATION_PRIMS = ("cholesky", "triangular_solve")


@dataclasses.dataclass(frozen=True)
class Violation:
    rule: str
    entry_point: str
    message: str
    provenance: str = ""

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Rule:
    name: str
    description: str
    applies: Callable[[object], bool]
    check: Callable[[object, object], list[Violation]]


@dataclasses.dataclass(frozen=True)
class RuleResult:
    rule: str
    entry_point: str
    passed: bool
    violations: tuple[Violation, ...] = ()

    def as_dict(self) -> dict:
        return {
            "rule": self.rule,
            "entry_point": self.entry_point,
            "passed": self.passed,
            "violations": [v.as_dict() for v in self.violations],
        }


def _v(rule, ep, msg, site=None) -> Violation:
    prov = ju.eqn_provenance(site.eqn) if site is not None else ""
    return Violation(rule=rule, entry_point=ep.name, message=msg,
                     provenance=prov)


# ---------------------------------------------------------------------------
# Rule 1: one-touch — no sketch-sized or A-copy intermediates outside the
# family's documented allowance; the streamed pass stays under its budget.
# ---------------------------------------------------------------------------

def _one_touch_applies(ep) -> bool:
    m = ep.meta
    return bool(m.get("family")) and all(
        k in m for k in ("B", "n", "d", "m_max"))


def _doubling_ladder(m_max: int) -> tuple[int, ...]:
    from repro.core.adaptive_padded import doubling_ladder

    return doubling_ladder(m_max)


def _stream_chunk(n: int) -> int:
    """The gaussian streamed pass's n-chunk: _MICRO = 256 column
    micro-tiles up to the 2048-column default (kernels.gaussian_gram)."""
    return min(-(-n // 256) * 256, 2048)


def _one_touch_check(ep, closed) -> list[Violation]:
    m = ep.meta
    fam, cd = m["family"], m.get("compute_dtype") or "fp32"
    B, n, d, m_max = m["B"], m["n"], m["d"], m["m_max"]
    n_pad = 1 << max(0, (n - 1).bit_length())
    chunk = _stream_chunk(n)
    out: list[Violation] = []

    # (a) the dense sketch (B, m_max, n) exists ONLY in the materialized
    # baseline family. Vacuous when n fits one stream chunk — the chunk
    # tile legitimately IS (B, m_max, n)-shaped there.
    if fam != "gaussian_dense" and n > chunk:
        sites = ju.find_intermediates(
            closed, lambda a: tuple(a.shape) == (B, m_max, n))
        for s in sites[:3]:
            out.append(_v("one_touch", ep,
                          f"dense sketch materialized: (B={B}, m_max={m_max},"
                          f" n={n}) intermediate in the {fam} family", s))

    # (b) no fp32 A-copy: a float32 (B, n, d) intermediate is a second
    # touch of the data (the weighted/sign-flipped copy every family
    # promises to fuse). Allowed: sjlt's ref-backend sign-scaled stream
    # copy; srht when n is already a power of two (the FWHT stack IS
    # (B, n_pad, d)); int8 mode's quantization-scale pass; n inside one
    # stream chunk (the chunk slice of A is full-A-shaped there).
    banned_a_copy = (fam in ("gaussian", "gaussian_dense", "srht")
                     and cd in ("fp32", "bf16")
                     and n > chunk
                     and not (fam == "srht" and n_pad == n))
    if banned_a_copy:
        sites = ju.find_intermediates(
            closed, lambda a: tuple(a.shape) == (B, n, d)
            and a.dtype == np.dtype(np.float32))
        for s in sites[:3]:
            out.append(_v("one_touch", ep,
                          f"fp32 (B, n, d) copy of A materialized in the "
                          f"{fam}/{cd} pass", s))

    # (c) streamed-pass peak budget: the gaussian family's largest live
    # intermediate stays within 2× the documented live set — the
    # (B, m_max, 256) generated micro-tile, the (B, chunk, d) A chunk,
    # the (L, B, d, d) Gram/inverse ladder and the (B, m_max, d) SA
    # accumulator (module docstring of core.level_grams) — which is ≥4×
    # below the dense sketch whenever the shapes can tell them apart.
    if fam == "gaussian":
        ladder_len = len(_doubling_ladder(m_max))
        live = 4 * max(B * m_max * 256, B * chunk * d,
                       ladder_len * B * d * d, B * m_max * d)
        budget = 2 * live
        peak, shape = ju.max_intermediate_bytes(closed)
        if peak > budget:
            out.append(Violation(
                "one_touch", ep.name,
                f"streamed gaussian peak {peak} B @ {shape} exceeds the "
                f"live-set budget {budget} B (dense S would be "
                f"{4 * B * m_max * n} B)"))

    # (d) SJLT single-dispatch: the cap level folds the one dispatch's
    # tail rows, so exactly ONE scatter-add touches A (CPU lowering of the
    # segment-sum; the provider graph is where the claim is crisp). The
    # path graphs inherit the claim wholesale: the entire λ grid rides
    # that single dispatch.
    if fam == "sjlt" and ep.kind in ("provider", "path"):
        n_scatter = ju.count_primitive(closed, ("scatter-add", "scatter_add"))
        if n_scatter != 1:
            out.append(Violation(
                "one_touch", ep.name,
                f"SJLT issued {n_scatter} scatter-add dispatches against A "
                f"(expected exactly 1, cap level included)"))

    # (e) λ-grid self-calibration (DESIGN.md §13): the FULL path graph —
    # shared precompute + P warm-started per-λ solves — consumes A exactly
    # as many times as its single-point reference. Equality means the grid
    # added ZERO touches of A: every per-λ cost (shifted factorizations,
    # solves) runs off the λ-free ladder. Self-calibrating by design; no
    # absolute count is asserted, so a legitimate change to the shared
    # pass cannot silently loosen the rule.
    ref_build = m.get("a_ref_build")
    if ref_build is not None:
        got = ju.count_a_consumers(closed, n, d)
        want = ju.count_a_consumers(ref_build(), n, d)
        if got != want:
            out.append(Violation(
                "one_touch", ep.name,
                f"{m.get('grid_points')}-point λ-grid graph consumes A "
                f"{got} times vs {want} in the single-point reference — "
                f"per-λ work re-touches A instead of riding the shared "
                f"λ-free ladder"))
    return out


# ---------------------------------------------------------------------------
# Rule 2: collective inventory — sharded precompute combines in exactly one
# psum of the Gram stack; the adaptive while_loop body is collective-free;
# unsharded graphs have no collectives at all.
# ---------------------------------------------------------------------------

def _collectives_check(ep, closed) -> list[Violation]:
    out: list[Violation] = []
    sites = ju.collect_sites(closed, COLLECTIVE_PRIMS)

    for s in sites:
        if s.in_while_body:
            out.append(_v("collective_inventory", ep,
                          f"collective `{s.primitive}` inside the adaptive "
                          f"while_loop body", s))

    if ep.kind == "sharded":
        budget = ep.meta.get("psum_budget", 1)
        psums = [s for s in sites if s.primitive.startswith("psum")]
        if len(psums) != budget:
            out.append(Violation(
                "collective_inventory", ep.name,
                f"sharded precompute lowered {len(psums)} psums "
                f"(budget: exactly {budget})"))
        want = ep.meta.get("psum_shape")
        if want is not None and psums:
            got = tuple(psums[0].eqn.outvars[0].aval.shape)
            if got != tuple(want):
                out.append(_v("collective_inventory", ep,
                              f"psum payload shape {got} != documented "
                              f"{tuple(want)}", psums[0]))
    elif sites:
        for s in sites[:3]:
            out.append(_v("collective_inventory", ep,
                          f"unexpected collective `{s.primitive}` in an "
                          f"unsharded graph", s))
    return out


# ---------------------------------------------------------------------------
# Rule 3: precision boundary — reduced-dtype values only flow into
# fp32-promoting contractions; factorizations, loop state and certificates
# are provably fp32; fp32 mode contains no reduced-precision values.
# ---------------------------------------------------------------------------

def _precision_check(ep, closed) -> list[Violation]:
    out: list[Violation] = []
    cd = ep.meta.get("compute_dtype") or "fp32"

    # (a) Cholesky / triangular solves never see reduced precision.
    for s in ju.collect_sites(closed, FACTORIZATION_PRIMS):
        dts = {str(v.aval.dtype) for v in s.eqn.invars
               if hasattr(v, "aval")}
        bad = dts - {"float32", "float64"}
        if bad:
            out.append(_v("precision_boundary", ep,
                          f"{s.primitive} operates on {sorted(bad)} "
                          f"(factorizations must be fp32)", s))

    # (b) the while_loop carry (iterates, residuals, δ̃ anchors — what the
    # certificates are computed from) holds no reduced-precision floats.
    for s in ju.collect_sites(closed, "while"):
        for var in s.eqn.outvars:
            if str(var.aval.dtype) in REDUCED_FLOAT:
                out.append(_v("precision_boundary", ep,
                              f"while_loop carries a {var.aval.dtype} value "
                              f"of shape {tuple(var.aval.shape)}", s))
                break

    # (c) every contraction with a reduced-float operand accumulates into
    # fp32 (`preferred_element_type` on the one designated boundary).
    for s in ju.collect_sites(closed, "dot_general"):
        in_dts = {str(v.aval.dtype) for v in s.eqn.invars
                  if hasattr(v, "aval")}
        if in_dts & set(REDUCED_FLOAT):
            out_dt = str(s.eqn.outvars[0].aval.dtype)
            if out_dt not in ("float32", "float64"):
                out.append(_v("precision_boundary", ep,
                              f"dot_general with {sorted(in_dts)} operands "
                              f"accumulates into {out_dt}, not fp32", s))
        if "int8" in in_dts:
            out_dt = str(s.eqn.outvars[0].aval.dtype)
            if out_dt not in ("float32", "float64", "int32"):
                out.append(_v("precision_boundary", ep,
                              f"int8 dot_general accumulates into {out_dt}",
                              s))

    # (d) fp32 mode is the pre-axis graph: no reduced floats anywhere.
    if cd == "fp32":
        sites = ju.find_intermediates(
            closed, lambda a: str(a.dtype) in REDUCED_FLOAT)
        for s in sites[:3]:
            out.append(_v("precision_boundary", ep,
                          f"reduced-precision intermediate in fp32 mode "
                          f"({s.primitive})", s))
    return out


def check_fp32_identity(family: str) -> list[Violation]:
    """``compute_dtype="fp32"`` must trace to an equation-identical graph
    as the pre-dtype-axis default (``compute_dtype=None``) — the fp32 mode
    is a no-op, not a third numerical regime."""
    import jax
    import jax.numpy as jnp

    from repro.core.adaptive_padded import doubling_ladder
    from repro.core.level_grams import get_provider

    from .entrypoints import M_MAX, N, _keys, _quadratic

    prov = get_provider(family)
    ladder = doubling_ladder(M_MAX)
    q = _quadratic()

    def trace(cd):
        def fn(q, keys):
            data = prov.sample(keys, M_MAX, N, jnp.float32)
            return prov.level_grams(data, q, ladder, compute_dtype=cd)

        return ju.jaxpr_text(jax.make_jaxpr(fn)(q, _keys()))

    if trace("fp32") != trace(None):
        return [Violation(
            "precision_boundary", f"provider:{family}:fp32:identity",
            f"compute_dtype='fp32' traces a different graph than the "
            f"pre-axis default for the {family} family")]
    return []


RULES: tuple[Rule, ...] = (
    Rule("one_touch",
         "A is consumed by exactly one streaming pass; no sketch-sized or "
         "A-copy intermediate outside the family's documented allowance",
         _one_touch_applies, _one_touch_check),
    Rule("collective_inventory",
         "exactly one psum combines the sharded ladder; the adaptive loop "
         "body is collective-free",
         lambda ep: True, _collectives_check),
    Rule("precision_boundary",
         "reduced-precision streams stop at the fp32-promoting contraction;"
         " Grams, Cholesky, δ̃ and certificates are provably fp32",
         lambda ep: True, _precision_check),
)
