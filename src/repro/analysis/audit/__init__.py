"""Invariant auditor: a jaxpr/HLO rule engine for the solver stack.

The paper's complexity claims rest on structural invariants — each sketch
family touches A exactly once, the sharded ladder combines in exactly ONE
psum, reduced-precision streams never cross the fp32 Gram/Cholesky/δ̃
boundary, entry points never silently retrace, PRNG keys reaching
sketches carry distinct coordinates. This package checks all of them
STATICALLY: every public entry point is traced to a closed jaxpr (never
executed), and a registry of declarative rules walks the equations.

    PYTHONPATH=src python -m repro.analysis.audit            # human report
    PYTHONPATH=src python -m repro.analysis.audit --json AUDIT.json
    PYTHONPATH=src python -m repro.analysis.audit --quick    # CI-fast subset

Layout:

* ``jaxpr_utils``  — the ONE jaxpr walker (sub-jaxpr recursion, primitive
  inventory, intermediate avals, eqn provenance). ``analysis.memscan`` and
  the tier-1 tests delegate here instead of keeping private copies.
* ``hlo_utils``    — optimized-HLO text scans (collective inventory,
  donation/aliasing markers). ``analysis.collectives`` delegates here.
* ``entrypoints``  — the audited surface: provider families × dtypes ×
  weighted, the engine segment executable, sharded precompute, Newton
  inner step, service pack/flush graphs.
* ``rules``        — the declarative rules (one-touch, collective
  inventory, precision boundary, retrace sentinel) + the registry.
* ``ast_rules``    — source-level lints (PRNG key hygiene, status-lattice
  handling) that do not need a trace at all.
* ``runner``       — run rules × entry points, emit AUDIT.json + report.
* ``fixtures``     — deliberately-violating graphs each rule must FAIL on
  (tests/test_audit.py proves every rule fires before trusting a pass).
"""

from .jaxpr_utils import (  # noqa: F401
    collect_eqns,
    count_primitive,
    eqn_provenance,
    has_intermediate_of_shape,
    iter_eqns,
    iter_intermediate_avals,
    jaxpr_text,
    max_intermediate_bytes,
    subjaxprs,
    while_body_jaxprs,
)
from .hlo_utils import (  # noqa: F401
    collective_bytes_from_hlo,
    donated_input_indices,
)
from .rules import RULES, Rule, RuleResult, Violation  # noqa: F401
from .entrypoints import ENTRY_POINTS, EntryPoint, build_targets  # noqa: F401
from .runner import AuditReport, run_audit  # noqa: F401
