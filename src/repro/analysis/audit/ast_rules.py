"""Source-level lints that need no trace: PRNG key hygiene and
status-lattice handling, over every module in ``src/repro``.

Key hygiene (DESIGN.md §6/§9): every key that reaches a sketch is derived
with ``fold_in``/``split`` using distinct coordinates — the service folds
request ids (padded slots take the reserved top-of-range stream), retries
fold the attempt index, shards fold the shard index, the Newton driver
folds the outer step. The statically-checkable residue of that contract:

* a module must not construct ``jax.random.PRNGKey(<literal>)`` twice
  with the SAME literal — two identical root keys in one module is how
  two "independent" sketches end up correlated;
* one function must not call ``fold_in(key, <literal>)`` twice with the
  same constant coordinate — that is the literal-reuse bug the slot-key
  scheme exists to prevent.

Status lattice (DESIGN.md §9): any module that consumes engine stats'
``status`` field must reference the lattice (``SolveStatus``,
``ENGINE_FAILURES``, ``status_name`` or ``CONVERGED_STATUSES``) — an
integer comparison against a bare literal silently breaks when the
lattice gains a member (exactly how DEADLINE_EXCEEDED was added).
"""

from __future__ import annotations

import ast
from pathlib import Path

from .rules import Violation

_LATTICE_NAMES = ("SolveStatus", "ENGINE_FAILURES", "status_name",
                  "CONVERGED_STATUSES")


def _is_call_named(node: ast.Call, name: str) -> bool:
    fn = node.func
    if isinstance(fn, ast.Name):
        return fn.id == name
    if isinstance(fn, ast.Attribute):
        return fn.attr == name
    return False


def _int_literal(node) -> int | None:
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return node.value
    return None


def lint_module_source(source: str, module_name: str,
                       path: str = "<string>") -> list[Violation]:
    """All key-hygiene + status-lattice findings for one module's source."""
    out: list[Violation] = []
    try:
        tree = ast.parse(source)
    except SyntaxError as e:  # unparsable files regress loudly
        return [Violation("key_hygiene", module_name,
                          f"unparsable source: {e}", f"{path}:{e.lineno}")]

    # -- PRNGKey literal reuse (module scope) -------------------------------
    seen_roots: dict[int, int] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_call_named(node, "PRNGKey"):
            if node.args:
                lit = _int_literal(node.args[0])
                if lit is None:
                    continue
                if lit in seen_roots:
                    out.append(Violation(
                        "key_hygiene", module_name,
                        f"PRNGKey({lit}) constructed twice (first at line "
                        f"{seen_roots[lit]}) — duplicate root keys correlate "
                        f"sketches", f"{path}:{node.lineno}"))
                else:
                    seen_roots[lit] = node.lineno

    # -- fold_in constant-coordinate reuse (function scope) -----------------
    for fn_node in ast.walk(tree):
        if not isinstance(fn_node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                    ast.Lambda)):
            continue
        seen_coords: dict[int, int] = {}
        for node in ast.walk(fn_node):
            if (isinstance(node, ast.Call)
                    and _is_call_named(node, "fold_in")
                    and len(node.args) >= 2):
                lit = _int_literal(node.args[1])
                if lit is None:
                    continue
                if lit in seen_coords:
                    fname = getattr(fn_node, "name", "<lambda>")
                    out.append(Violation(
                        "key_hygiene", module_name,
                        f"fold_in(…, {lit}) called twice in `{fname}` "
                        f"(first at line {seen_coords[lit]}) — reused "
                        f"coordinates yield identical derived keys",
                        f"{path}:{node.lineno}"))
                else:
                    seen_coords[lit] = node.lineno

    # -- status-lattice handling -------------------------------------------
    reads_status = any(
        isinstance(node, ast.Subscript)
        and isinstance(node.slice, ast.Constant)
        and node.slice.value == "status"
        and isinstance(node.value, ast.Name)
        and "stats" in node.value.id
        for node in ast.walk(tree))
    if reads_status and not any(n in source for n in _LATTICE_NAMES):
        out.append(Violation(
            "status_lattice", module_name,
            "consumes engine stats['status'] without referencing the "
            "status lattice (SolveStatus / ENGINE_FAILURES / status_name)",
            path))
    return out


def lint_tree(root: str | Path = "src/repro") -> list[Violation]:
    """Lint every module under ``root`` (the audit package's own fixtures
    are skipped — they exist to violate)."""
    root = Path(root)
    out: list[Violation] = []
    for f in sorted(root.rglob("*.py")):
        if f.name == "fixtures.py" and "audit" in f.parts:
            continue
        rel = f.relative_to(root.parent if root.name == "repro" else root)
        out.extend(lint_module_source(
            f.read_text(), str(rel).replace("/", ".").removesuffix(".py"),
            str(f)))
    return out
