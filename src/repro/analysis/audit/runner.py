"""Run rules × entry points; emit AUDIT.json and the human report.

    PYTHONPATH=src python -m repro.analysis.audit [--json AUDIT.json]
                                                  [--quick] [--no-exec]
                                                  [--entry SUBSTR] [--rule R]

Exit code 0 iff every applicable rule passes on every entry point (CI
gates on this). ``AUDIT.json`` is the machine-readable matrix: rule →
entry point → pass/fail plus offending-equation provenance — what lets a
perf-trajectory row (``benchmarks/run.py --json``) be correlated with the
invariant status at that commit.
"""

from __future__ import annotations

import dataclasses
import json
import sys
import time

from .ast_rules import lint_tree
from .entrypoints import build_targets
from .rules import RULES, RuleResult, Violation, check_fp32_identity


@dataclasses.dataclass
class AuditReport:
    results: list[RuleResult]
    elapsed_s: float
    quick: bool

    @property
    def passed(self) -> bool:
        return all(r.passed for r in self.results)

    @property
    def n_failed(self) -> int:
        return sum(not r.passed for r in self.results)

    def summary(self) -> dict:
        """The compact pass/fail summary benchmarks embed next to rows."""
        by_rule: dict[str, dict] = {}
        for r in self.results:
            cell = by_rule.setdefault(r.rule, {"checked": 0, "failed": 0})
            cell["checked"] += 1
            cell["failed"] += not r.passed
        return {"passed": self.passed, "checks": len(self.results),
                "failed": self.n_failed, "quick": self.quick,
                "by_rule": by_rule}

    def as_dict(self) -> dict:
        return {
            "passed": self.passed,
            "elapsed_s": round(self.elapsed_s, 1),
            "summary": self.summary(),
            "results": [r.as_dict() for r in self.results],
        }

    def human_report(self) -> str:
        lines = []
        by_rule: dict[str, list[RuleResult]] = {}
        for r in self.results:
            by_rule.setdefault(r.rule, []).append(r)
        for rule, rs in sorted(by_rule.items()):
            n_bad = sum(not r.passed for r in rs)
            mark = "FAIL" if n_bad else "ok"
            lines.append(f"[{mark:4s}] {rule}: {len(rs) - n_bad}/{len(rs)} "
                         f"entry points clean")
            for r in rs:
                if r.passed:
                    continue
                for v in r.violations:
                    where = f"  {v.provenance}" if v.provenance else ""
                    lines.append(f"       ✗ {r.entry_point}: {v.message}"
                                 f"{where}")
        verdict = "PASS" if self.passed else "FAIL"
        lines.append(f"audit: {verdict} ({len(self.results)} checks, "
                     f"{self.n_failed} failed, {self.elapsed_s:.1f}s)")
        return "\n".join(lines)


def _group(violations: list[Violation], rule: str,
           entry_point: str) -> RuleResult:
    mine = tuple(v for v in violations
                 if v.rule == rule and v.entry_point == entry_point)
    return RuleResult(rule=rule, entry_point=entry_point,
                      passed=not mine, violations=mine)


def run_audit(quick: bool = False, run_exec: bool = True,
              entry_filter: str = "", rule_filter: str = "",
              src_root: str = "src/repro") -> AuditReport:
    """The whole gate. ``run_exec=False`` skips the behavioral checks
    (retrace sentinel / donation), which execute tiny problems — everything
    else is pure tracing + AST."""
    t0 = time.time()
    results: list[RuleResult] = []

    def want(rule_name: str) -> bool:
        return not rule_filter or rule_filter in rule_name

    # -- jaxpr rules over the traced surface --------------------------------
    for ep in build_targets(quick=quick):
        if entry_filter and entry_filter not in ep.name:
            continue
        applicable = [r for r in RULES if want(r.name) and r.applies(ep)]
        if not applicable:
            continue
        closed = ep.build()
        for rule in applicable:
            try:
                vs = rule.check(ep, closed)
            except Exception as e:  # a crashed rule is a failed rule
                vs = [Violation(rule.name, ep.name,
                                f"rule crashed: {type(e).__name__}: {e}")]
            results.append(RuleResult(
                rule=rule.name, entry_point=ep.name, passed=not vs,
                violations=tuple(vs)))

    # -- fp32 ≡ pre-axis equation identity ----------------------------------
    if want("precision_boundary") and not entry_filter:
        from repro.core.level_grams import PADDED_SKETCHES

        for family in PADDED_SKETCHES if not quick else ("gaussian",):
            vs = check_fp32_identity(family)
            results.append(RuleResult(
                rule="precision_boundary",
                entry_point=f"provider:{family}:fp32:identity",
                passed=not vs, violations=tuple(vs)))

    # -- source lints -------------------------------------------------------
    if not entry_filter:
        lint_vs = lint_tree(src_root)
        for rule_name in ("key_hygiene", "status_lattice"):
            if not want(rule_name):
                continue
            mine = tuple(v for v in lint_vs if v.rule == rule_name)
            results.append(RuleResult(
                rule=rule_name, entry_point=src_root, passed=not mine,
                violations=mine))

    # -- behavioral checks (execute tiny problems) --------------------------
    if run_exec and not entry_filter and want("retrace_sentinel"):
        from .retrace import run_behavioral_checks

        vs = run_behavioral_checks()
        eps = sorted({v.entry_point for v in vs}) or ["engine:lifecycle"]
        for ep_name in eps:
            results.append(_group(list(vs), "retrace_sentinel", ep_name))

    return AuditReport(results=results, elapsed_s=time.time() - t0,
                       quick=quick)


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.audit",
        description="statically audit the solver stack's invariants")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="also write the machine-readable AUDIT.json")
    ap.add_argument("--quick", action="store_true",
                    help="CI-fast subset (fp32 only, one service class)")
    ap.add_argument("--no-exec", action="store_true",
                    help="skip the behavioral retrace/donation checks")
    ap.add_argument("--entry", default="",
                    help="only entry points whose name contains this")
    ap.add_argument("--rule", default="",
                    help="only rules whose name contains this")
    ap.add_argument("--src-root", default="src/repro")
    args = ap.parse_args(argv)

    report = run_audit(quick=args.quick, run_exec=not args.no_exec,
                       entry_filter=args.entry, rule_filter=args.rule,
                       src_root=args.src_root)
    print(report.human_report())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report.as_dict(), f, indent=2)
        print(f"wrote {args.json}")
    return 0 if report.passed else 1


if __name__ == "__main__":
    sys.exit(main())
