"""The audited surface: every public entry point, traced — never executed.

Each :class:`EntryPoint` knows how to build its closed jaxpr from
``ShapeDtypeStruct`` arguments (``jax.make_jaxpr`` needs avals only, so
even the n = 65536 pod-scale service class traces in ~a second on a
laptop) plus the metadata rules key on: sketch family, compute dtype,
weightedness, and the per-family shape allowances of the one-touch claim.

The point of a *registry* is that new entry points are audited by
default: a fifth provider family lands in ``PADDED_SKETCHES`` and
immediately appears in the families × dtypes × weighted product below; a
new service shape class is picked up from ``DEFAULT_SHAPE_CLASSES``.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.adaptive_padded import (
    PADDED_METHODS,
    doubling_ladder,
    finalize_padded_solve,
    padded_adaptive_solve_batched,
    padded_path_solve_batched,
    padded_solve_segment,
    prepare_padded_solve,
    prepare_path_ladder,
)
from repro.core.level_grams import PADDED_SKETCHES, get_provider
from repro.core.quadratic import Quadratic
from repro.kernels.precision import COMPUTE_DTYPES

# Audit shapes: big enough that the memory claims bind (the streamed-pass
# peak budget is meaningless when n-chunking pads past n), small enough
# that d×d factorizations trace instantly. n is deliberately NOT a power
# of two so the SRHT pad-to-n_pad path is exercised.
B, N, D, M_MAX = 3, 2000, 16, 128


@dataclasses.dataclass(frozen=True)
class EntryPoint:
    """One audited entry point: ``build()`` returns its ClosedJaxpr."""

    name: str
    kind: str                      # provider | engine | sharded | segment |
    build: Callable[[], object]    # newton | service
    meta: dict


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _quadratic(b=B, n=N, d=D, weighted=False):
    return Quadratic(
        A=_sds((b, n, d)), b=_sds((b, d)), nu=_sds((b,)),
        lam_diag=_sds((b, d)), batched=True,
        row_weights=_sds((b, n)) if weighted else None)


def _keys(b=B):
    return jax.random.split(jax.random.PRNGKey(0), b)


def _provider_ep(family: str, cd: str, weighted: bool) -> EntryPoint:
    def build():
        prov = get_provider(family)
        ladder = doubling_ladder(M_MAX)
        q = _quadratic(weighted=weighted)

        def fn(q, keys):
            data = prov.sample(keys, M_MAX, N, jnp.float32)
            return prov.level_grams(data, q, ladder, compute_dtype=cd)

        return jax.make_jaxpr(fn)(q, _keys())

    w = "weighted" if weighted else "unweighted"
    return EntryPoint(
        name=f"provider:{family}:{cd}:{w}", kind="provider", build=build,
        meta={"family": family, "compute_dtype": cd, "weighted": weighted,
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _engine_ep(family: str, method: str, cd: str) -> EntryPoint:
    def build():
        q = _quadratic()
        return jax.make_jaxpr(
            lambda q, k: padded_adaptive_solve_batched(
                q, k, m_max=M_MAX, method=method, sketch=family,
                compute_dtype=cd)[0])(q, _keys())

    return EntryPoint(
        name=f"engine:{family}:{method}:{cd}", kind="engine", build=build,
        meta={"family": family, "method": method, "compute_dtype": cd,
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _segment_ep() -> EntryPoint:
    """The re-dispatched segment executable + finalize, traced from the
    prepare-time state SHAPES (``jax.eval_shape`` — prepare itself never
    runs)."""

    def build():
        q = _quadratic()
        pre, st = jax.eval_shape(
            lambda q, k: prepare_padded_solve(q, k, m_max=M_MAX),
            q, _keys())
        return jax.make_jaxpr(
            lambda q, pre, st, lim: finalize_padded_solve(
                pre, padded_solve_segment(q, pre, st, lim, method="pcg"),
                m_max=M_MAX))(q, pre, st, _sds((), jnp.int32))

    return EntryPoint(
        name="engine:segment:pcg:fp32", kind="segment", build=build,
        meta={"family": "gaussian", "method": "pcg", "compute_dtype": "fp32",
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _sharded_ep(family: str) -> EntryPoint:
    """The one-psum ladder precompute on a 1-device mesh: shard_map traces
    identically at any device count, so the collective *inventory* (how
    many psums, of what) is auditable without an 8-device subprocess."""

    def build():
        from repro.core.distributed import shard_level_grams

        mesh = jax.make_mesh((1,), ("data",))
        prov = get_provider(family)
        ladder = doubling_ladder(M_MAX)
        q = _quadratic()
        return jax.make_jaxpr(
            lambda q, ks: shard_level_grams(prov, ks, q, ladder, mesh))(
                q, _keys())

    return EntryPoint(
        name=f"sharded:{family}:fp32", kind="sharded", build=build,
        meta={"family": family, "compute_dtype": "fp32", "psum_budget": 1,
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _sharded_weighted_gram_ep() -> EntryPoint:
    def build():
        from repro.core.distributed import shard_weighted_gram

        mesh = jax.make_mesh((1,), ("data",))
        q = _quadratic(weighted=True)
        return jax.make_jaxpr(
            lambda q: shard_weighted_gram(q, mesh))(q)

    return EntryPoint(
        name="sharded:weighted_gram", kind="sharded", build=build,
        meta={"family": None, "compute_dtype": "fp32", "psum_budget": 1,
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _newton_inner_ep() -> EntryPoint:
    """The Newton driver's inner solve: the weighted engine with a warm
    ``init_level`` — exactly what ``core.newton`` dispatches per step."""

    def build():
        q = _quadratic(weighted=True)
        return jax.make_jaxpr(
            lambda q, k, lvl: padded_adaptive_solve_batched(
                q, k, m_max=M_MAX, method="pcg", sketch="gaussian",
                init_level=lvl)[0])(q, _keys(), _sds((B,), jnp.int32))

    return EntryPoint(
        name="newton:inner:gaussian:fp32", kind="newton", build=build,
        meta={"family": "gaussian", "method": "pcg", "compute_dtype": "fp32",
              "weighted": True, "B": B, "n": N, "d": D, "m_max": M_MAX})


def _newton_step_ep(family: str = "logistic") -> EntryPoint:
    """The driver's per-step jitted pieces (gradient/Hessian weights and
    the vmapped Armijo line search) as one traced graph."""

    def build():
        from repro.core.newton import _grad_and_weights, _line_search
        from repro.core.objectives import get_objective

        obj = get_objective(family)
        A, y = _sds((B, N, D)), _sds((B, N))
        nu, lam = _sds((B,)), _sds((B, D))
        x, delta = _sds((B, D)), _sds((B, D))
        dec, active = _sds((B,)), _sds((B,), jnp.bool_)

        def fn(A, y, nu, lam, x, delta, dec, active):
            g, w = _grad_and_weights(obj, A, y, nu, lam, x)
            return _line_search(obj, A, y, nu, lam, x, delta, dec, active,
                                backtracks=12, c1=1e-4), g, w

        return jax.make_jaxpr(fn)(A, y, nu, lam, x, delta, dec, active)

    return EntryPoint(
        name=f"newton:step:{family}", kind="newton", build=build,
        meta={"family": family, "compute_dtype": "fp32",
              "B": B, "n": N, "d": D})


def _path_ladder_ep(family: str) -> EntryPoint:
    """The λ-free path precompute (DESIGN.md §13): the one-touch ladder
    pass + optional true-Gram precompute that one entire λ grid shares.
    The same graph is the unit the serving ladder cache stores."""

    def build():
        q = _quadratic()
        return jax.make_jaxpr(
            lambda q, k: prepare_path_ladder(
                q, k, m_max=M_MAX, sketch=family))(q, _keys())

    return EntryPoint(
        name=f"path:ladder:{family}", kind="path", build=build,
        meta={"family": family, "compute_dtype": "fp32",
              "B": B, "n": N, "d": D, "m_max": M_MAX})


def _path_grid_ep(family: str, points: int = 3) -> EntryPoint:
    """The FULL λ-grid path solve as ONE traced graph: the shared ladder
    pass plus ``points`` warm-started per-λ solves. ``a_ref_build`` hands
    the one-touch rule a single-point reference graph so it can verify
    the grid adds ZERO extra consumers of A (self-calibrating — no
    absolute count is asserted); the collective rule covers the per-point
    while_loop bodies like any other engine graph."""

    def graph(P):
        q = _quadratic()

        def fn(q, keys, nus):
            return padded_path_solve_batched(
                q, keys, nus, m_max=M_MAX, method="pcg", sketch=family)[0]

        return jax.make_jaxpr(fn)(q, _keys(), _sds((P, B)))

    return EntryPoint(
        name=f"path:grid:{family}", kind="path",
        build=lambda: graph(points),
        meta={"family": family, "method": "pcg", "compute_dtype": "fp32",
              "B": B, "n": N, "d": D, "m_max": M_MAX,
              "grid_points": points, "a_ref_build": lambda: graph(1)})


def _path_sharded_ep() -> EntryPoint:
    """The sharded path precompute: the SAME per-shard one-touch pass +
    ONE psum of the (L, B, d, d) level Grams serves the entire λ grid
    (the grid itself adds no collectives — the level Grams are λ-free)."""

    def build():
        mesh = jax.make_mesh((1,), ("data",))
        q = _quadratic()
        return jax.make_jaxpr(
            lambda q, k: prepare_path_ladder(
                q, k, m_max=M_MAX, sketch="gaussian", mesh=mesh))(
                    q, _keys())

    return EntryPoint(
        name="path:sharded:gaussian:fp32", kind="sharded", build=build,
        meta={"family": "gaussian", "compute_dtype": "fp32",
              "psum_budget": 1, "B": B, "n": N, "d": D, "m_max": M_MAX})


def _service_pack_keys_ep() -> EntryPoint:
    """The pack path's slot-key derivation: ONE vmapped fold_in over the
    slot-id vector (real slots: req_id; padded slots: 2³²−1−slot)."""

    def build():
        def fn(base_key, slot_ids):
            return jax.vmap(
                lambda i: jax.random.fold_in(base_key, i))(slot_ids)

        return jax.make_jaxpr(fn)(
            _sds((2,), jnp.uint32), _sds((16,), jnp.uint32))

    return EntryPoint(
        name="service:pack_keys", kind="service", build=build,
        meta={"compute_dtype": None})


def _service_class_ep(cls) -> EntryPoint:
    """The engine graph a flush dispatches for one shape class, at the
    class's padded dims, sketch family and compute dtype."""

    def build():
        q = _quadratic(b=4, n=cls.n, d=cls.d)
        return jax.make_jaxpr(
            lambda q, k: padded_adaptive_solve_batched(
                q, k, m_max=cls.m_max, method="pcg",
                sketch=cls.sketch or "gaussian",
                compute_dtype=cls.compute_dtype or "fp32")[0])(
                    q, _keys(4))

    fam = cls.sketch or "gaussian"
    cd = cls.compute_dtype or "fp32"
    return EntryPoint(
        name=f"service:class:n{cls.n}:d{cls.d}:{fam}:{cd}", kind="service",
        build=build,
        meta={"family": fam, "method": "pcg", "compute_dtype": cd,
              "B": 4, "n": cls.n, "d": cls.d, "m_max": cls.m_max})


def build_targets(quick: bool = False) -> list[EntryPoint]:
    """The full audited surface (or the CI-quick subset: one dtype, the
    engine's default method, the smallest service class)."""
    eps: list[EntryPoint] = []
    dtypes = ("fp32",) if quick else COMPUTE_DTYPES
    for family in PADDED_SKETCHES:
        for cd in dtypes:
            for weighted in (False, True):
                eps.append(_provider_ep(family, cd, weighted))
    for family in PADDED_SKETCHES:
        eps.append(_engine_ep(family, "pcg", "fp32"))
    if not quick:
        for method in PADDED_METHODS:
            if method != "pcg":
                eps.append(_engine_ep("gaussian", method, "fp32"))
        for cd in ("bf16", "int8"):
            eps.append(_engine_ep("gaussian", "pcg", cd))
    eps.append(_segment_ep())
    for family in PADDED_SKETCHES:
        if quick and family != "gaussian":
            continue
        eps.append(_path_ladder_ep(family))
        eps.append(_path_grid_ep(family))
    for family in PADDED_SKETCHES:
        if quick and family != "gaussian":
            continue
        eps.append(_sharded_ep(family))
    eps.append(_path_sharded_ep())
    eps.append(_sharded_weighted_gram_ep())
    eps.append(_newton_inner_ep())
    eps.append(_newton_step_ep("logistic"))
    eps.append(_service_pack_keys_ep())
    from repro.serve.solver_service import DEFAULT_SHAPE_CLASSES

    classes = DEFAULT_SHAPE_CLASSES[:1] if quick else DEFAULT_SHAPE_CLASSES
    for cls in classes:
        eps.append(_service_class_ep(cls))
    return eps


ENTRY_POINTS = build_targets  # legacy alias: callable registry
