"""Exact FLOP counting from optimized (partitioned) HLO text.

``compiled.cost_analysis()`` proved unreliable for large SPMD programs
(loop bodies counted once; at 2²¹×8192 scale the reported flops diverged
~500× from the dot instructions actually present in the module). This
module counts flops from first principles: every ``dot`` instruction in
the partitioned module contributes 2 · prod(output_dims) · prod(contracted
lhs dims). Shapes in the partitioned module are per-device, so the result
is per-device flops — the quantity the roofline compute term needs.

HLO operands are referenced by NAME (``dot(%a.1, %b.1)``), so parsing is
two-pass: build a name → shape table from every instruction definition,
then resolve each dot's lhs shape and contracting dims.

Limitations (documented in EXPERIMENTS.md): while-loop bodies are counted
once (the solver probe unrolls its PCG scan in the analysis sweep, so all
iterations are present); elementwise flops are ignored (≤ a few % for
these workloads); cholesky/triangular-solve flops are added analytically
by the caller when relevant (``roofline.solver_model_flops``).

``dot_flops_for_entry`` connects this counter to the audited solver
surface: any entry point from ``repro.analysis.audit.entrypoints`` can be
compiled for the host platform and measured without executing.
"""

from __future__ import annotations

import re
from collections import Counter

_DTYPES = r"(?:pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64|f64|f8\w*)"
_DEF_RE = re.compile(rf"%([\w.\-]+) = {_DTYPES}\[([0-9,]*)\]")
_DOT_LINE_RE = re.compile(
    rf"%[\w.\-]+ = {_DTYPES}\[([0-9,]*)\][^\n]*?\bdot\(([^)]*)\)"
)
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_BATCH_RE = re.compile(r"lhs_batch_dims=\{([0-9,]*)\}")
# operands carry inline shapes in newer HLO text: dot(f32[3,128,256]{...} %a, …)
_OPERAND_RE = re.compile(rf"(?:{_DTYPES}\[([0-9,]*)\]\S*\s+)?%([\w.\-]+)")


def _prod(dims_csv: str) -> int:
    out = 1
    for t in dims_csv.split(","):
        if t:
            out *= int(t)
    return out


def _name_shapes(hlo_text: str) -> dict[str, list[int]]:
    table: dict[str, list[int]] = {}
    for m in _DEF_RE.finditer(hlo_text):
        dims = [int(t) for t in m.group(2).split(",") if t]
        table[m.group(1)] = dims
    return table


def iter_dots(hlo_text: str):
    """Yields (out_dims_csv, flops) per dot instruction (per device)."""
    shapes = _name_shapes(hlo_text)
    for line in hlo_text.splitlines():
        if "dot(" not in line:
            continue
        m = _DOT_LINE_RE.search(line)
        if not m:
            continue
        out_csv, operands = m.group(1), m.group(2)
        mc = _CONTRACT_RE.search(line)
        if not mc:
            continue
        mo = _OPERAND_RE.search(operands)
        if mo is None:
            continue
        if mo.group(1) is not None:         # inline-shaped operand
            lhs_dims = [int(t) for t in mo.group(1).split(",") if t]
        else:                               # name-referenced operand
            lhs_dims = shapes.get(mo.group(2))
        if lhs_dims is None:
            continue
        contracted = 1
        for i in (int(t) for t in mc.group(1).split(",") if t):
            if i < len(lhs_dims):
                contracted *= lhs_dims[i]
        yield out_csv, 2.0 * _prod(out_csv) * contracted


def dot_flops_from_hlo(hlo_text: str) -> float:
    """Sum of 2·|out|·|contracted| over all dots (per device)."""
    return sum(fl for _, fl in iter_dots(hlo_text))


def dot_flops_for_entry(entry_name: str) -> float:
    """Per-device dot FLOPs of one audited solver entry point (exact name
    from ``repro.analysis.audit.entrypoints.build_targets``), compiled for
    the host platform — lowered and counted, never executed."""
    import jax

    from .audit.entrypoints import build_targets

    for ep in build_targets(quick=False):
        if ep.name == entry_name:
            closed = ep.build()
            fn = jax.core.jaxpr_as_fun(closed)
            args = [jax.ShapeDtypeStruct(a.shape, a.dtype)
                    for a in closed.in_avals]
            hlo = jax.jit(fn).lower(*args).compile().as_text()
            return dot_flops_from_hlo(hlo)
    raise KeyError(f"unknown audit entry point: {entry_name}")


def dot_inventory(hlo_text: str, top: int = 12):
    """[(out_shape, count, flops_each)] sorted by total flops — triage."""
    inv: Counter = Counter()
    fl_each: dict[str, float] = {}
    for out_csv, fl in iter_dots(hlo_text):
        inv[out_csv] += 1
        fl_each[out_csv] = fl
    rows = sorted(
        ((k, c, fl_each[k]) for k, c in inv.items()),
        key=lambda t: -t[1] * t[2],
    )
    return rows[:top]
