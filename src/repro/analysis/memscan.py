"""Jaxpr shape scans — thin delegation onto the audit rule engine.

The streamed sketch→Gram path promises "S never materializes": no
intermediate of shape (B, m_max, n) anywhere in the program. The walker
that verifies this lives in :mod:`repro.analysis.audit.jaxpr_utils` now
(one shared recursion into scan/while/cond/pjit/shard_map bodies, used by
the invariant auditor, the benchmarks and the tier-1 tests alike); this
module keeps the historical import surface.
"""

from __future__ import annotations

from .audit.jaxpr_utils import (  # noqa: F401
    has_intermediate_of_shape,
    iter_intermediate_avals,
    max_intermediate_bytes,
)
