"""Jaxpr shape scans: verify streaming claims without running anything.

The streamed sketch→Gram path promises "S never materializes": no
intermediate of shape (B, m_max, n) anywhere in the program. These helpers
walk a jaxpr (recursing into all sub-jaxprs — scan/while/cond/pjit bodies)
and report every intermediate array, so tests can assert the promise and
benchmarks can report an analytical peak-live-bytes next to the compiled
``memory_analysis()`` numbers.
"""

from __future__ import annotations

from typing import Iterable

import jax
import numpy as np


def _subjaxprs(eqn) -> Iterable:
    for v in eqn.params.values():
        if isinstance(v, jax.core.ClosedJaxpr):
            yield v.jaxpr
        elif isinstance(v, jax.core.Jaxpr):
            yield v
        elif isinstance(v, (tuple, list)):
            for item in v:
                if isinstance(item, jax.core.ClosedJaxpr):
                    yield item.jaxpr
                elif isinstance(item, jax.core.Jaxpr):
                    yield item


def iter_intermediate_avals(closed_jaxpr) -> Iterable:
    """Yield the aval of every equation output, recursively."""
    stack = [closed_jaxpr.jaxpr]
    seen = set()
    while stack:
        jx = stack.pop()
        if id(jx) in seen:
            continue
        seen.add(id(jx))
        for eqn in jx.eqns:
            for var in eqn.outvars:
                aval = getattr(var, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    yield aval
            stack.extend(_subjaxprs(eqn))


def max_intermediate_bytes(closed_jaxpr) -> tuple[int, tuple[int, ...]]:
    """(bytes, shape) of the single largest intermediate array produced
    anywhere in the program (sub-jaxprs included)."""
    best, best_shape = 0, ()
    for aval in iter_intermediate_avals(closed_jaxpr):
        nbytes = int(np.prod(aval.shape, dtype=np.int64)) * aval.dtype.itemsize
        if nbytes > best:
            best, best_shape = nbytes, tuple(aval.shape)
    return best, best_shape


def has_intermediate_of_shape(closed_jaxpr, shape: tuple[int, ...]) -> bool:
    """True if any intermediate anywhere has exactly this shape."""
    shape = tuple(shape)
    return any(tuple(a.shape) == shape
               for a in iter_intermediate_avals(closed_jaxpr))
