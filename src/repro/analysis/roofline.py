"""Roofline analysis from the dry-run artifacts (TPU v5e-class constants).

Per (arch × shape × mesh) cell:
    compute    = HLO_FLOPs        / (chips · 197e12 FLOP/s bf16)
    memory     = HLO_bytes        / (chips · 819e9  B/s HBM)
    collective = collective_bytes / (chips · 50e9   B/s per ICI link)

Conventions (validated against the compiled artifacts):
* ``cost_analysis()`` on a GSPMD-partitioned executable reports the
  *per-device* program, so FLOPs/bytes are multiplied by the device count
  to get cluster totals, then divided back per the formulas — i.e. the
  terms below use per-device values directly (chips cancels).
* collective_bytes comes from summing collective op output sizes in the
  optimized (post-partitioning) HLO — also per-device.
* MODEL_FLOPS = 6·N·D for training (fwd 2ND + bwd 4ND), 2·N_active·D for
  inference, with D = global tokens processed by the step.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

from repro.configs import SHAPES, get_config

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step_kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bottleneck: str
    step_time_s: float       # max of the three terms (no-overlap bound)
    roofline_frac: float     # compute_s / step_time_s (MFU-like upper bound)
    mfu: float               # model_flops / (chips·peak·step_time)
    per_device_bytes: dict

    def row(self) -> dict:
        return dataclasses.asdict(self)


def model_flops_for(arch: str, shape: str) -> float:
    cfg = get_config(arch)
    spec = SHAPES[shape]
    n_active = cfg.active_param_count()
    n_total = cfg.param_count()
    if spec.step == "train":
        tokens = spec.global_batch * spec.seq_len
        return 6.0 * n_active * tokens
    if spec.step == "prefill":
        tokens = spec.global_batch * spec.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * spec.global_batch


def scan_corrections(arch: str, shape: str, chips: int) -> tuple[float, float]:
    """Analytic per-device (flops, bytes) for time-major ``lax.scan`` bodies
    that XLA's cost model counts once (the layer scans are unrolled in the
    analysis sweep, but rwkv6's wkv recurrence scans over T and cannot be
    unrolled at T = 4k–500k). Per step and head: y = Sᵀr (2·hd²), outer
    k·vᵀ (hd²), decay·S + add (2·hd²) ⇒ ≈5·hd² flops; state RW ⇒ ≈8·hd²
    bytes (f32). Training doubles for the backward scan. Everything else
    (attention, MLPs, RG-LRU associative_scan) is fully counted."""
    cfg = get_config(arch)
    if "rwkv" not in cfg.pattern:
        return 0.0, 0.0
    spec = SHAPES[shape]
    T = spec.seq_len if spec.step in ("train", "prefill") else 1
    if T <= 1:
        return 0.0, 0.0
    dp = max(chips // 16, 1)  # model=16 on both production meshes
    b_loc = max(spec.global_batch // dp, 1)
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    per_step_flops = 5.0 * hd * hd * H * b_loc
    per_step_bytes = 8.0 * hd * hd * H * b_loc  # f32 state read+write
    mult = 2.0 if spec.step == "train" else 1.0  # bwd replays the scan
    extra_steps = (T - 1) * cfg.n_layers * mult
    return extra_steps * per_step_flops, extra_steps * per_step_bytes


def analyze_record(rec: dict) -> Roofline | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    # prefer the instruction-level dot count (cost_analysis() diverges on
    # large SPMD modules — see analysis/hloflops.py); keep the larger of
    # the two (each can only under-count)
    flops_dev = max(rec.get("hlo_dot_flops") or 0.0, rec["flops"] or 0.0)
    bytes_dev = rec["bytes_accessed"] or 0.0
    coll_dev = rec["collectives"]["total_bytes"]
    cf, cb = scan_corrections(rec["arch"], rec["shape"], chips)
    flops_dev += cf
    bytes_dev += cb

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW

    mf = model_flops_for(rec["arch"], rec["shape"])
    hlo_total = flops_dev * chips
    useful = mf / hlo_total if hlo_total else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    mfu = mf / (chips * PEAK_FLOPS * step_time) if step_time else 0.0
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        step_kind=rec.get("step_kind", "?"),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_total=hlo_total, useful_ratio=useful,
        bottleneck=bottleneck, step_time_s=step_time,
        roofline_frac=compute_s / step_time if step_time else 0.0,
        mfu=mfu,
        per_device_bytes=rec.get("memory", {}),
    )


def load_all(results_dir: str | Path = "results/dryrun") -> list[Roofline]:
    out = []
    for f in sorted(Path(results_dir).glob("*/*.json")):
        r = analyze_record(json.loads(f.read_text()))
        if r:
            out.append(r)
    return out


def markdown_table(rows: list[Roofline]) -> str:
    hdr = (
        "| arch | shape | mesh | step | compute (s) | memory (s) | "
        "collective (s) | bottleneck | useful FLOPs | MFU bound |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.step_kind} "
            f"| {r.compute_s:.3e} | {r.memory_s:.3e} | {r.collective_s:.3e} "
            f"| **{r.bottleneck}** | {r.useful_ratio:.2f} | {r.mfu:.3f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = [r for r in load_all(args.dir) if r.mesh == args.mesh]
    print(markdown_table(rows))
    worst = sorted(rows, key=lambda r: r.mfu)[:5]
    print("\nworst MFU cells:")
    for r in worst:
        print(f"  {r.arch}/{r.shape}: mfu={r.mfu:.4f} bn={r.bottleneck}")
    coll = sorted(rows, key=lambda r: -(r.collective_s / max(r.step_time_s, 1e-30)))[:5]
    print("most collective-bound:")
    for r in coll:
        print(f"  {r.arch}/{r.shape}: coll/step={r.collective_s/r.step_time_s:.2f}")


if __name__ == "__main__":
    main()
