"""Roofline analysis from the dry-run artifacts (TPU v5e-class constants).

Per (entry point × shape × mesh) cell:
    compute    = HLO_FLOPs        / (chips · 197e12 FLOP/s bf16)
    memory     = HLO_bytes        / (chips · 819e9  B/s HBM)
    collective = collective_bytes / (chips · 50e9   B/s per ICI link)

The first-class records are the SOLVER entry points
(``launch/dryrun_solver.py``: arch = ``solver-ridge-<variant>``, shape =
``probe_2m_8k``): useful work is the paper's algorithm — sketch, Gram,
Cholesky, PCG iterations — counted analytically from the probe dims, so
``useful_ratio`` measures how much of the lowered program is the
algorithm vs partitioning overhead. Legacy model-config cells (the
pre-solver dry-run heritage) still analyze via a lazy ``repro.configs``
fallback and are skipped when the config is unknown.

Conventions (validated against the compiled artifacts):
* ``cost_analysis()`` on a GSPMD-partitioned executable reports the
  *per-device* program, so the terms below use per-device values directly
  (chips cancels in the time formulas).
* collective_bytes comes from summing collective op output sizes in the
  optimized (post-partitioning) HLO — also per-device.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link

# the ridge-probe dims every solver dry-run cell uses
# (launch/dryrun_solver.py)
SOLVER_SHAPES = {
    "probe_2m_8k": dict(n=1 << 21, d=8192, c=1024, m=16384, pcg_iters=10),
}


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    step_kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops: float
    hlo_flops_total: float
    useful_ratio: float
    bottleneck: str
    step_time_s: float       # max of the three terms (no-overlap bound)
    roofline_frac: float     # compute_s / step_time_s (MFU-like upper bound)
    mfu: float               # model_flops / (chips·peak·step_time)
    per_device_bytes: dict

    def row(self) -> dict:
        return dataclasses.asdict(self)


def solver_model_flops(arch: str, shape: str) -> float:
    """Analytic FLOPs of one adaptive phase of the paper's solver at the
    probe dims: sketch + Gram + Cholesky + PCG iterations. This is the
    'useful work' numerator — anything the lowered HLO does beyond it is
    partitioning/layout overhead."""
    dims = SOLVER_SHAPES[shape]
    n, d, c, m, iters = (dims["n"], dims["d"], dims["c"], dims["m"],
                         dims["pcg_iters"])
    if "gaussian" in arch:
        sketch = 2.0 * m * n * d          # dense S @ A
    else:
        sketch = 2.0 * n * d              # SJLT: each row touched once
    gram = 2.0 * m * d * d                # SAᵀ SA
    chol = d ** 3 / 3.0
    # per PCG iteration: Hv = Aᵀ(Av) on the (d, c) RHS block + the
    # two (d, d)-triangular preconditioner solves on (d, c)
    pcg = iters * (4.0 * n * d * c + 2.0 * d * d * c)
    return sketch + gram + chol + pcg


def model_flops_for(arch: str, shape: str) -> float:
    """Useful-FLOPs numerator for any dry-run record; solver cells are
    analytic (above), legacy model cells go through ``repro.configs``."""
    if arch.startswith("solver"):
        return solver_model_flops(arch, shape)
    # legacy transformer cells (pre-solver dry-run heritage)
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    spec = SHAPES[shape]
    n_active = cfg.active_param_count()
    if spec.step == "train":
        return 6.0 * n_active * spec.global_batch * spec.seq_len
    if spec.step == "prefill":
        return 2.0 * n_active * spec.global_batch * spec.seq_len
    return 2.0 * n_active * spec.global_batch   # decode: 1 token/sequence


def analyze_record(rec: dict) -> Roofline | None:
    if rec.get("status") != "ok":
        return None
    chips = rec["n_devices"]
    # prefer the instruction-level dot count (cost_analysis() diverges on
    # large SPMD modules — see analysis/hloflops.py); keep the larger of
    # the two (each can only under-count)
    flops_dev = max(rec.get("hlo_dot_flops") or 0.0, rec["flops"] or 0.0)
    bytes_dev = rec["bytes_accessed"] or 0.0
    coll_dev = rec["collectives"]["total_bytes"]

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW

    try:
        mf = model_flops_for(rec["arch"], rec["shape"])
    except KeyError:
        return None     # unknown legacy config: nothing to normalize by
    hlo_total = flops_dev * chips
    useful = mf / hlo_total if hlo_total else 0.0
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step_time = max(terms.values())
    mfu = mf / (chips * PEAK_FLOPS * step_time) if step_time else 0.0
    return Roofline(
        arch=rec["arch"], shape=rec["shape"], mesh=rec["mesh"],
        step_kind=rec.get("step_kind", "?"),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        model_flops=mf, hlo_flops_total=hlo_total, useful_ratio=useful,
        bottleneck=bottleneck, step_time_s=step_time,
        roofline_frac=compute_s / step_time if step_time else 0.0,
        mfu=mfu,
        per_device_bytes=rec.get("memory", {}),
    )


def load_all(results_dir: str | Path = "results/dryrun") -> list[Roofline]:
    out = []
    for f in sorted(Path(results_dir).glob("*/*.json")):
        r = analyze_record(json.loads(f.read_text()))
        if r:
            out.append(r)
    return out


def markdown_table(rows: list[Roofline]) -> str:
    hdr = (
        "| arch | shape | mesh | step | compute (s) | memory (s) | "
        "collective (s) | bottleneck | useful FLOPs | MFU bound |\n"
        "|---|---|---|---|---|---|---|---|---|---|\n"
    )
    lines = []
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.step_kind} "
            f"| {r.compute_s:.3e} | {r.memory_s:.3e} | {r.collective_s:.3e} "
            f"| **{r.bottleneck}** | {r.useful_ratio:.2f} | {r.mfu:.3f} |"
        )
    return hdr + "\n".join(lines) + "\n"


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    rows = [r for r in load_all(args.dir) if r.mesh == args.mesh]
    print(markdown_table(rows))
    worst = sorted(rows, key=lambda r: r.mfu)[:5]
    print("\nworst MFU cells:")
    for r in worst:
        print(f"  {r.arch}/{r.shape}: mfu={r.mfu:.4f} bn={r.bottleneck}")
    coll = sorted(rows, key=lambda r: -(r.collective_s / max(r.step_time_s, 1e-30)))[:5]
    print("most collective-bound:")
    for r in coll:
        print(f"  {r.arch}/{r.shape}: coll/step={r.collective_s/r.step_time_s:.2f}")


if __name__ == "__main__":
    main()
