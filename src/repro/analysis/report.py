"""Render EXPERIMENTS.md §Dry-run and §Roofline from the sweep artifacts.

    PYTHONPATH=src python -m repro.analysis.report > EXPERIMENTS_generated.md

The §Dry-run table comes from results/dryrun (the production programs:
scanned layers, real microbatching — proves compile + memory); §Roofline
comes from results/dryrun_analysis (unrolled scans, nmb=1 — accurate
FLOP/byte/collective accounting; see the note in the section header).
"""

from __future__ import annotations

import json
from pathlib import Path

from .roofline import analyze_record, markdown_table


def dryrun_table(results_dir="results/dryrun") -> str:
    rows = []
    for f in sorted(Path(results_dir).glob("*/*.json")):
        r = json.loads(f.read_text())
        if r["status"] == "skipped":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | skipped | — | — "
                f"| — | {r['reason'][:58]} |"
            )
            continue
        if r["status"] != "ok":
            rows.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR | — | — "
                f"| — | {r.get('error','')[:58]} |"
            )
            continue
        mem = r["memory"]
        args_gb = (mem.get("argument_size_in_bytes") or 0) / 2**30
        temp_gb = (mem.get("temp_size_in_bytes") or 0) / 2**30
        coll_gb = r["collectives"]["total_bytes"] / 2**30
        n_coll = sum(v["count"] for v in r["collectives"]["by_op"].values())
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {args_gb:.2f} | {temp_gb:.2f} | {coll_gb:.2f} ({n_coll}) "
            f"| compile {r['compile_s']}s |"
        )
    hdr = (
        "| arch | shape | mesh | status | args GiB/dev | temp GiB/dev | "
        "collective GiB/dev (#ops) | notes |\n|---|---|---|---|---|---|---|---|\n"
    )
    return hdr + "\n".join(rows) + "\n"


def roofline_section(results_dir="results/dryrun_analysis") -> str:
    recs = []
    for f in sorted(Path(results_dir).glob("single/*.json")):
        r = analyze_record(json.loads(f.read_text()))
        if r:
            recs.append(r)
    out = [markdown_table(recs)]
    out.append("\nPer-cell bottleneck sentences:\n")
    for r in recs:
        solver = r.arch.startswith("solver")
        if r.bottleneck == "memory":
            s = ("increase arithmetic intensity: fuse/avoid activation "
                 "round-trips, larger per-device microbatch, bf16 cache")
            if solver:
                s = ("the A-stream dominates — the sketch and each PCG "
                     "matvec re-read the row shard; bf16 matvecs halve the "
                     "stream, fusing sketch+first-matvec removes one pass")
        elif r.bottleneck == "collective":
            s = ("reduce resharding: co-shard embed/logits with the "
                 "attention layout; overlap gathers with compute")
            if solver:
                s = ("the per-iteration AᵀAv partial-sum all-reduce "
                     "dominates — block PCG iterations or move to the "
                     "one-psum ladder precompute (core.distributed)")
        else:
            s = "compute-bound — already at the MXU roofline knee"
        out.append(f"* **{r.arch}/{r.shape}** → {r.bottleneck}-bound; {s}.\n")
    return "".join(out)


def main():
    print("## §Dry-run (production programs, 16×16 and 2×16×16 meshes)\n")
    print(dryrun_table())
    print("\n## §Roofline (single-pod, analysis sweep)\n")
    print(roofline_section())


if __name__ == "__main__":
    main()
