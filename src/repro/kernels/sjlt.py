"""Pallas TPU kernel: SJLT sketch as one-hot MXU matmuls.

The SJLT applies S (one signed non-zero per column) to A: a segment-sum
    (SA)[r, :] = Σ_{i : row(i)=r} sign(i) · A[i, :].
On CPU/GPU this is a scatter-add; scatters are hostile to the TPU (serialized
through the scalar unit). TPU adaptation (DESIGN.md §3): per row-block of A,
build the signed one-hot dispatch matrix on the fly from (rows, signs) via
``broadcasted_iota`` comparison and contract it with the A tile on the MXU:

    out += OneHot(rows_blk)ᵀ_signed (m × br) @ A_blk (br × d).

The grid walks row blocks sequentially; the output block is revisited
(index_map constant) and accumulated in place — the standard Pallas
accumulator pattern. Dense systolic work replaces data-dependent scatter:
bandwidth-bound instead of latency-bound.

Batched variant (DESIGN.md §6): ``sjlt_pallas_batched`` adds a leading
problem axis to the grid — grid (B, n/br), one dispatch-matmul cell per
(problem, row-block). The problem axis is the outer (slowest) grid
dimension, so each problem's output block sees its row-blocks sequentially
and the same revisited-accumulator pattern applies per problem. The data
matrix may be per-problem (B, n, d) or shared (n, d) across the batch
(λ-sweep / multi-tenant serving); in the shared case the A tile is fetched
once per row-block index by the pipeline, not once per problem.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .precision import canonical_compute_dtype, contract_dtype


def fold_row_weights(signs: jnp.ndarray,
                     row_weights: jnp.ndarray | None) -> jnp.ndarray:
    """Weighted SJLT = S·diag(w^{1/2}): the sketch has one signed non-zero
    per column, so scaling column i by w_i^{1/2} is exactly scaling its
    sign — an O(n) elementwise fold on the (…, n) sign stream, never an
    (n, d) weighted copy of A (DESIGN.md §8)."""
    if row_weights is None:
        return signs
    return signs * jnp.sqrt(row_weights).astype(signs.dtype)


def fold_stream(A: jnp.ndarray, signs: jnp.ndarray,
                compute_dtype: str | None):
    """The SJLT's compute-dtype prep (``kernels.precision``), shared by the
    Pallas wrappers and the segment-sum oracle: on the int8 path A is
    quantized per row and the dequantization scales fold into the sign
    stream — exactly the ``fold_row_weights`` algebra, because the sketch
    has one signed non-zero per column, so S·diag(s)·codes scales sign i by
    s_i. Returns (A_stream, signs, contract dtype, out dtype)."""
    name = canonical_compute_dtype(compute_dtype)
    ct = contract_dtype(name)
    if name == "int8" and A.dtype != jnp.int8:
        from repro.dist.compress import quantize_rows

        codes, a_scales = quantize_rows(A)
        if a_scales.ndim < signs.ndim:        # shared A under batched signs
            a_scales = a_scales[None, :]
        signs = signs * a_scales
        A = codes
    out_dtype = jnp.float32 if (name != "fp32" or A.dtype == jnp.int8
                                ) else A.dtype
    return A, signs, ct, out_dtype


def _sjlt_kernel(rows_ref, signs_ref, a_ref, o_ref, *, m: int, ct):
    i = pl.program_id(0)
    rows = rows_ref[...]            # (br,) int32 target row per A-row
    signs = signs_ref[...]          # (br,) ±1/√s (× w^{1/2} / int8 scales)
    a = a_ref[...]                  # (br, bd)
    br = a.shape[0]
    # signed one-hot dispatch (m, br) built in VMEM; ct is the contract
    # dtype (fp32/bf16) — bf16 folds the sign stream into the MXU's native
    # mixed mode, fp32 accumulation via preferred_element_type either way
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (m, br), 0)
    onehot = jnp.where(row_ids == rows[None, :], signs[None, :], 0.0).astype(
        ct
    )
    acc = jnp.dot(onehot, a.astype(ct), preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = acc.astype(o_ref.dtype)

    @pl.when(i > 0)
    def _acc():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + acc).astype(o_ref.dtype)


def sjlt_pallas(
    A: jnp.ndarray,
    rows: jnp.ndarray,
    signs: jnp.ndarray,
    m: int,
    *,
    block_rows: int = 256,
    interpret: bool = False,
    row_weights: jnp.ndarray | None = None,
    compute_dtype: str | None = None,
) -> jnp.ndarray:
    """S @ A for an s=1 SJLT. A: (n, d); rows/signs: (n,). Returns (m, d).
    ``row_weights`` (n,) computes S·W^{1/2}·A by folding w^{1/2} into the
    sign stream (``fold_row_weights``); ``compute_dtype`` runs the
    dispatch-matmul in bf16 / streams int8 codes (``fold_stream``).

    VMEM per step: br·d (A tile) + m·br (one-hot) + m·d (accumulator);
    with br=256, m≤2048, d-tile = full d this targets ≤ ~8 MiB for d ≤ 4k.
    """
    signs = fold_row_weights(signs, row_weights)
    A, signs, ct, out_dtype = fold_stream(A, signs, compute_dtype)
    n, d = A.shape
    if n % block_rows:
        pad = (-n) % block_rows
        A = jnp.pad(A, ((0, pad), (0, 0)))
        rows = jnp.pad(rows, (0, pad), constant_values=m)  # m = out of range
        signs = jnp.pad(signs, (0, pad))
        n = A.shape[0]
    grid = (n // block_rows,)
    out = pl.pallas_call(
        functools.partial(_sjlt_kernel, m=m, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((m, d), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), out_dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), signs.astype(jnp.float32), A)
    return out


def _sjlt_kernel_batched(rows_ref, signs_ref, a_ref, o_ref, *, m: int, ct):
    j = pl.program_id(1)            # row-block index (inner grid dim)
    rows = rows_ref[0, :]           # (br,) this problem's targets
    signs = signs_ref[0, :]
    a = a_ref[...]                  # (br, d) or (1, br, d) per-problem
    if a.ndim == 3:
        a = a[0]
    br = a.shape[0]
    row_ids = jax.lax.broadcasted_iota(jnp.int32, (m, br), 0)
    onehot = jnp.where(row_ids == rows[None, :], signs[None, :], 0.0).astype(
        ct
    )
    acc = jnp.dot(onehot, a.astype(ct), preferred_element_type=jnp.float32)

    @pl.when(j == 0)
    def _init():
        o_ref[0, ...] = acc.astype(o_ref.dtype)

    @pl.when(j > 0)
    def _acc():
        o_ref[0, ...] = (o_ref[0, ...].astype(jnp.float32) + acc).astype(
            o_ref.dtype
        )


def sjlt_pallas_batched(
    A: jnp.ndarray,
    rows: jnp.ndarray,
    signs: jnp.ndarray,
    m: int,
    *,
    block_rows: int = 256,
    interpret: bool = False,
    row_weights: jnp.ndarray | None = None,
    compute_dtype: str | None = None,
) -> jnp.ndarray:
    """Batch of s=1 SJLT sketches: one dispatch-matmul grid cell per
    (problem, row-block). A: (B, n, d) per-problem or (n, d) shared;
    rows/signs: (B, n). Returns (B, m, d). ``row_weights`` (B, n) folds
    per-problem w^{1/2} into the sign stream (``fold_row_weights``) — the
    shared-A fast path survives per-problem weights because the weight
    lives in the per-problem sketch, not in A. ``compute_dtype``
    (``kernels.precision``): bf16 dispatch-matmuls, or int8 A codes with
    the per-row dequantization scales folded into the sign stream
    (``fold_stream``) — the shared-A fast path survives quantization for
    the same reason it survives weights.

    The problem axis is the outer grid dimension so the per-problem output
    block accumulates over its row-blocks exactly as in ``sjlt_pallas``;
    VMEM per step is unchanged from the single-problem kernel.
    """
    signs = fold_row_weights(signs, row_weights)
    A, signs, ct, out_dtype = fold_stream(A, signs, compute_dtype)
    B, n = rows.shape
    shared = A.ndim == 2
    d = A.shape[-1]
    if A.shape[-2] != n:
        raise ValueError(f"A rows {A.shape[-2]} != sketch columns {n}")
    if n % block_rows:
        pad = (-n) % block_rows
        pad_a = ((0, pad), (0, 0)) if shared else ((0, 0), (0, pad), (0, 0))
        A = jnp.pad(A, pad_a)
        rows = jnp.pad(rows, ((0, 0), (0, pad)), constant_values=m)
        signs = jnp.pad(signs, ((0, 0), (0, pad)))
        n = A.shape[-2]
    grid = (B, n // block_rows)
    a_spec = (
        pl.BlockSpec((block_rows, d), lambda b, j: (j, 0))
        if shared
        else pl.BlockSpec((1, block_rows, d), lambda b, j: (b, j, 0))
    )
    out = pl.pallas_call(
        functools.partial(_sjlt_kernel_batched, m=m, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_rows), lambda b, j: (b, j)),
            pl.BlockSpec((1, block_rows), lambda b, j: (b, j)),
            a_spec,
        ],
        out_specs=pl.BlockSpec((1, m, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, d), out_dtype),
        interpret=interpret,
    )(rows.astype(jnp.int32), signs.astype(jnp.float32), A)
    return out
