"""Pure-jnp oracles for the Pallas kernels (shape/dtype-sweep allclose)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def fwht_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Unnormalized FWHT along axis 0. x: (n, d), n a power of two."""
    n, d = x.shape
    if n & (n - 1):
        raise ValueError("n must be a power of 2")
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, d)
        a, b = x[:, 0], x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=1)
        h *= 2
    return x.reshape(n, d)


def sjlt_ref(A: jnp.ndarray, rows: jnp.ndarray, signs: jnp.ndarray, m: int,
             compute_dtype: str | None = None) -> jnp.ndarray:
    """Segment-sum oracle for the SJLT kernel. ``compute_dtype`` mirrors the
    kernel's MXU arithmetic: operands rounded to the contract dtype, the
    signed products and their segment accumulation exact in fp32."""
    from .sjlt import fold_stream

    A, signs, ct, out_dtype = fold_stream(A, signs, compute_dtype)
    sim = lambda v: v.astype(ct).astype(jnp.float32)
    out = jax.ops.segment_sum(sim(A) * sim(signs)[:, None], rows,
                              num_segments=m)
    return out.astype(out_dtype)


def sjlt_ref_batched(A: jnp.ndarray, rows: jnp.ndarray, signs: jnp.ndarray,
                     m: int, compute_dtype: str | None = None) -> jnp.ndarray:
    """Batched oracle: A (B, n, d) or shared (n, d); rows/signs (B, n).
    Out-of-range targets (row index ≥ m, used for padding) drop out, as in
    the kernel. Returns (B, m, d)."""
    from .sjlt import fold_stream

    A, signs, ct, out_dtype = fold_stream(A, signs, compute_dtype)
    sim = lambda v: v.astype(ct).astype(jnp.float32)
    one = lambda A_b, r_b, s_b: jax.ops.segment_sum(
        sim(A_b) * sim(s_b)[:, None], r_b, num_segments=m)
    in_axes = (None, 0, 0) if A.ndim == 2 else (0, 0, 0)
    return jax.vmap(one, in_axes=in_axes)(A, rows, signs).astype(out_dtype)


def hadamard_dense(n: int) -> jnp.ndarray:
    """Dense Hadamard matrix (tiny-n ground truth)."""
    H = jnp.ones((1, 1), jnp.float32)
    while H.shape[0] < n:
        H = jnp.block([[H, H], [H, -H]])
    return H
