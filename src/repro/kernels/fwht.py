"""Pallas TPU kernel: Fast Walsh–Hadamard transform (the SRHT hot spot).

The paper's SRHT sketch S·A = √(n/m)·R·H·E·A is dominated by the FWHT
H·(E·A) over the n-dimension of A (cost O(n·d·log n)). On CPU/GPU this is a
recursive butterfly; TPU-native design (DESIGN.md §3):

* A is processed in column tiles: a (n, bc) tile of the sign-flipped matrix
  lives in VMEM (BlockSpec over the d axis), padded so n is a power of two.
* All log₂(n) butterfly stages run *inside one kernel invocation* on the
  VPU via reshape/concat butterflies — no HBM round-trips between stages
  (a CPU implementation is memory-bound precisely because each stage
  streams n·d elements; fusing stages in VMEM turns log n passes into one).
* For n too large for VMEM, the radix split H_n = (H_a ⊗ I_b)·(I_a ⊗ H_b)
  in ``ops.fwht_large`` runs two kernel passes with a transpose between,
  each pass transforming a VMEM-resident axis.

Grid: (d / bc,) — one program per column tile; row axis is not tiled
(the butterfly couples all n rows).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwht_kernel(x_ref, o_ref, *, n: int):
    """One column tile: x_ref (n, bc) in VMEM; all stages in-register."""
    x = x_ref[...]
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, x.shape[-1])
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = x.reshape(n, x.shape[-1])


def _fwht_kernel_scaled(s_ref, x_ref, o_ref, *, n: int):
    """Fused H·diag(s)·x: the per-row scale (SRHT signs, optionally folded
    with GLM weights w^{1/2}) is applied to the VMEM tile before the
    butterfly — the scaled matrix diag(s)·x never round-trips HBM."""
    x = x_ref[...] * s_ref[...][:, None]
    h = 1
    while h < n:
        x = x.reshape(n // (2 * h), 2, h, x.shape[-1])
        a = x[:, 0]
        b = x[:, 1]
        x = jnp.concatenate([a + b, a - b], axis=1)
        h *= 2
    o_ref[...] = x.reshape(n, x.shape[-1])


def fwht_pallas(
    x: jnp.ndarray,
    *,
    block_cols: int = 128,
    interpret: bool = False,
    row_scale: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Unnormalized FWHT along axis 0 of x (n, d); n must be a power of 2.
    ``row_scale`` (n,) fuses H·diag(s)·x in one kernel (see
    ``_fwht_kernel_scaled``).

    VMEM budget: n · block_cols · 4 bytes (f32) ≤ ~8 MiB ⇒ block_cols 128
    handles n ≤ 16384; use ``ops.fwht_large`` beyond that.
    """
    n, d = x.shape
    if n & (n - 1):
        raise ValueError(f"n={n} must be a power of 2")
    bc = min(block_cols, d)
    pad = (-d) % bc
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    dp = x.shape[1]

    if row_scale is None:
        out = pl.pallas_call(
            functools.partial(_fwht_kernel, n=n),
            grid=(dp // bc,),
            in_specs=[pl.BlockSpec((n, bc), lambda j: (0, j))],
            out_specs=pl.BlockSpec((n, bc), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
            interpret=interpret,
        )(x)
    else:
        out = pl.pallas_call(
            functools.partial(_fwht_kernel_scaled, n=n),
            grid=(dp // bc,),
            in_specs=[
                pl.BlockSpec((n,), lambda j: (0,)),
                pl.BlockSpec((n, bc), lambda j: (0, j)),
            ],
            out_specs=pl.BlockSpec((n, bc), lambda j: (0, j)),
            out_shape=jax.ShapeDtypeStruct((n, dp), x.dtype),
            interpret=interpret,
        )(row_scale.astype(x.dtype), x)
    return out[:, :d] if pad else out
