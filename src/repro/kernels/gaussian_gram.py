"""Pallas TPU kernel: streaming fused Gaussian sketch→(SA) with in-kernel PRNG.

The padded adaptive engine precomputes sketched Grams at every doubling-
ladder level. Materializing the Gaussian sketch S (B, m_max, n) in HBM and
pushing it through an einsum is memory-bound and allocates O(B·m_max·n) —
the opposite of the paper's O(n·d) sketch-pass accounting. This kernel
never materializes S: each grid cell *generates* its (m_max, chunk) tile of
S on the fly from a counter-based PRNG in VMEM and contracts it with the
matching A chunk on the MXU, accumulating SA (B, m_max, d) with the
standard revisited-output pattern (DESIGN.md §3). A is streamed exactly
once in n-chunks; live memory is O(B·m_max·d) ≪ O(B·m_max·n).

PRNG design: entries are a pure function of (problem seed, row, column) —
a murmur3-finalizer counter hash feeding Box–Muller — so

* the kernel and the chunked ``lax.scan`` oracle (``gaussian_sa_ref``, the
  CPU/GPU path) draw bit-identical sketch entries;
* numerics are *chunk-invariant by construction*: the oracle reduces the
  n axis at a fixed ``_MICRO``-column granularity in a fixed order, so any
  public chunk size produces bit-identical SA (tested);
* no backend-specific PRNG primitive is needed — the hash is plain uint32
  jnp arithmetic, so the same kernel body compiles on TPU Mosaic and runs
  under ``interpret=True`` on CPU.

Counters pack (row, col) as ``row·2^20 + col`` in uint32, which is
injective for n ≤ 2^20 columns and m_max ≤ 2^12 rows — far above any
sketch this engine builds (m_max is a few·d); asserted in the wrappers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .precision import canonical_compute_dtype, contract_dtype

# Canonical micro-tile of the n axis: the oracle always reduces n in
# _MICRO-column steps so chunk size never changes numerics; the Pallas
# kernel requires chunk % _MICRO == 0 so its tiles see the same counters.
_MICRO = 256
_COL_BITS = 20                 # counters: row · 2^20 + col
MAX_N = 1 << _COL_BITS         # column capacity of the counter packing
MAX_M = 1 << (32 - _COL_BITS)  # row capacity

# numpy scalars (not jnp arrays): they inline as jaxpr literals, which a
# Pallas kernel body may close over — committed device arrays may not
_GOLD = np.uint32(0x9E3779B9)
_SEQ2 = np.uint32(0x7F4A7C15)
_MUL1 = np.uint32(0x85EBCA6B)
_MUL2 = np.uint32(0xC2B2AE35)


def _mix(x: jnp.ndarray) -> jnp.ndarray:
    """murmur3 finalizer: a bijective uint32 avalanche."""
    x = (x ^ (x >> 16)) * _MUL1
    x = (x ^ (x >> 13)) * _MUL2
    return x ^ (x >> 16)


def gaussian_tile(seed, row0, col0, shape) -> jnp.ndarray:
    """(shape) float32 tile of the seed's N(0,1) sketch at (row0, col0).

    Pure uint32 jnp arithmetic + Box–Muller, usable identically inside a
    Pallas kernel body and in plain jitted code. ``seed``/``row0``/``col0``
    may be traced scalars.
    """
    r = jnp.uint32(row0) + jax.lax.broadcasted_iota(jnp.uint32, shape, 0)
    c = jnp.uint32(col0) + jax.lax.broadcasted_iota(jnp.uint32, shape, 1)
    ctr = (r << _COL_BITS) + c
    k = _mix(jnp.uint32(seed) ^ _GOLD)
    h1 = _mix(ctr ^ k)
    h2 = _mix(h1 + _SEQ2)
    # 24-bit mantissas; u1 offset into (0, 1) so log(u1) is finite
    u1 = (h1 >> 8).astype(jnp.float32) * (1.0 / 16777216.0) + (
        0.5 / 16777216.0)
    u2 = (h2 >> 8).astype(jnp.float32) * (1.0 / 16777216.0)
    return jnp.sqrt(-2.0 * jnp.log(u1)) * jnp.cos(6.2831853071795864 * u2)


def _check_caps(n: int, m: int) -> None:
    if n > MAX_N or m > MAX_M:
        raise ValueError(
            f"counter packing supports n ≤ {MAX_N}, m ≤ {MAX_M}; "
            f"got n={n}, m={m}")


def gaussian_s_dense(seeds: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Materialize the full (B, m, n) sketch — the dense baseline/oracle.

    Entry [b, r, c] is exactly what the streaming kernel/oracle generate
    for problem b at (row r, column c)."""
    _check_caps(n, m)
    return jax.vmap(lambda s: gaussian_tile(s, 0, 0, (m, n)))(seeds)


# ---------------------------------------------------------------------------
# Chunked lax.scan oracle — the CPU/GPU streaming path
# ---------------------------------------------------------------------------

def resolve_stream(A: jnp.ndarray, B: int,
                   row_weights: jnp.ndarray | None,
                   compute_dtype: str | None):
    """The Gaussian family's compute-dtype prep, shared by the oracle, the
    Pallas wrapper and the dense provider (``kernels.precision``).

    Folds everything that scales the generated S tile's columns into ONE
    per-column fp32 scale: the GLM w^{1/2} (as before) and, on the int8
    path, the per-row dequantization scales of the quantized A — so the
    kernels dequantize in-register by construction, streaming int8 codes
    and multiplying diag(scales) into the tile they already generate.

    Returns (A_stream, scale (B, n) | None, contract dtype, out dtype).
    """
    name = canonical_compute_dtype(compute_dtype)
    ct = contract_dtype(name)
    scale = (None if row_weights is None
             else jnp.sqrt(row_weights.astype(jnp.float32)))
    if name == "int8" and A.dtype != jnp.int8:
        from repro.dist.compress import quantize_rows

        codes, a_scales = quantize_rows(A)
        if A.ndim == 2:                       # shared A: broadcast per problem
            a_scales = jnp.broadcast_to(a_scales[None, :], (B, A.shape[0]))
        scale = a_scales if scale is None else scale * a_scales
        A = codes
    out_dtype = jnp.float32 if (name != "fp32" or A.dtype == jnp.int8
                                ) else A.dtype
    return A, scale, ct, out_dtype


def gaussian_sa_ref(A: jnp.ndarray, seeds: jnp.ndarray, m: int, *,
                    chunk_cols: int = 2048,
                    row_weights: jnp.ndarray | None = None,
                    compute_dtype: str | None = None) -> jnp.ndarray:
    """Streamed S @ A without materializing S: (B, m, d) from A (n, d)
    shared or (B, n, d) per-problem and per-problem uint32 seeds (B,).

    ``lax.scan`` walks n-chunks of A; inside each step a ``fori_loop``
    reduces the chunk in fixed _MICRO-column micro-tiles, so the sequence
    of partial products — and therefore the result, bit-for-bit — is
    independent of ``chunk_cols`` (which only sets live-memory/pipelining
    granularity). Peak live sketch state is (B, m, _MICRO) + the (B, m, d)
    accumulator.

    ``row_weights`` (B, n): computes S·W^{1/2}·A by scaling the generated
    (B, m, _MICRO) S tile columns by w^{1/2} inside the stream — the
    weighted matrix W^{1/2}A never exists (DESIGN.md §8).

    ``compute_dtype`` (``kernels.precision``): ``"bf16"`` casts the scaled
    S micro-tile and the A micro-slice to bfloat16 before the contraction
    (``preferred_element_type=float32`` keeps the accumulator exact fp32);
    ``"int8"`` additionally streams per-row-quantized codes of A with the
    dequantization scales folded into the same per-column tile scale as
    the weights. The fixed-micro-tile reduction order is dtype-independent,
    so chunk invariance holds bit-for-bit PER dtype."""
    shared = A.ndim == 2
    n, d = A.shape[-2], A.shape[-1]
    B = seeds.shape[0]
    _check_caps(n, m)
    A, scale, ct, out_dtype = resolve_stream(A, B, row_weights, compute_dtype)
    k = max(1, -(-chunk_cols // _MICRO))      # micro-tiles per scan step
    k = min(k, -(-n // _MICRO))               # never pad n past one chunk
    chunk = k * _MICRO
    pad = (-n) % chunk
    if pad:
        # zero columns: their generated sketch entries multiply 0.0, and
        # acc + 0.0 is exact, so padding never changes the result
        A = jnp.pad(A, ((0, pad), (0, 0)) if shared
                    else ((0, 0), (0, pad), (0, 0)))
        if scale is not None:
            scale = jnp.pad(scale, ((0, 0), (0, pad)))
    steps = (n + pad) // chunk
    if shared:
        contract = lambda S, a: jnp.einsum(
            "bmc,cd->bmd", S, a, preferred_element_type=jnp.float32)
    else:
        contract = lambda S, a: jnp.einsum(
            "bmc,bcd->bmd", S, a, preferred_element_type=jnp.float32)

    def step(acc, c_idx):
        # A is sliced in place (no re-layout copy): the only live sketch
        # state is the (B, m, _MICRO) tile and the (B, m, d) accumulator
        def micro(i, acc):
            col0 = c_idx * chunk + i * _MICRO
            S = jax.vmap(lambda s: gaussian_tile(
                s, 0, col0.astype(jnp.uint32), (m, _MICRO)))(seeds)
            if scale is not None:
                s_mu = jax.lax.dynamic_slice_in_dim(
                    scale, col0, _MICRO, axis=1)
                S = S * s_mu[:, None, :]
            a_mu = jax.lax.dynamic_slice_in_dim(
                A, col0, _MICRO, axis=A.ndim - 2)
            return acc + contract(S.astype(ct), a_mu.astype(ct))

        return jax.lax.fori_loop(0, k, micro, acc), None

    acc0 = jnp.zeros((B, m, d), jnp.float32)
    acc, _ = jax.lax.scan(step, acc0, jnp.arange(steps))
    return acc.astype(out_dtype)


# ---------------------------------------------------------------------------
# Pallas kernel — grid (B, n/chunk), S tile generated in VMEM per cell
# ---------------------------------------------------------------------------

def _gauss_sa_kernel(seed_ref, a_ref, o_ref, *, m: int, chunk: int, ct):
    c = pl.program_id(1)
    seed = seed_ref[0]
    col0 = (c * chunk).astype(jnp.uint32)
    S = gaussian_tile(seed, 0, col0, (m, chunk))   # VMEM-only, never in HBM
    a = a_ref[...]
    if a.ndim == 3:
        a = a[0]
    # ct is the contract dtype (kernels.precision): fp32 or bf16. The cast
    # happens on the VMEM tile/chunk in-register; the MXU accumulates fp32
    # via preferred_element_type either way.
    acc = jnp.dot(S.astype(ct), a.astype(ct),
                  preferred_element_type=jnp.float32)

    @pl.when(c == 0)
    def _init():
        o_ref[0, ...] = acc.astype(o_ref.dtype)

    @pl.when(c > 0)
    def _acc():
        o_ref[0, ...] = (o_ref[0, ...].astype(jnp.float32) + acc).astype(
            o_ref.dtype)


def _gauss_sa_kernel_scaled(seed_ref, s_ref, a_ref, o_ref, *, m: int,
                            chunk: int, ct):
    """Scaled variant: the generated (m, chunk) S tile's columns are scaled
    by a pre-folded fp32 per-column factor in VMEM before the MXU
    contraction — w^{1/2} (GLM weights), int8 dequantization scales, or
    their product (``resolve_stream``) all ride the same slot. S·diag(s)·A
    fused, with neither S nor the scaled A ever in HBM; on the int8 path
    ``a`` holds codes that are dequantized in-register by this scale."""
    c = pl.program_id(1)
    seed = seed_ref[0]
    col0 = (c * chunk).astype(jnp.uint32)
    S = gaussian_tile(seed, 0, col0, (m, chunk))
    S = S * s_ref[0, :].astype(jnp.float32)[None, :]
    a = a_ref[...]
    if a.ndim == 3:
        a = a[0]
    acc = jnp.dot(S.astype(ct), a.astype(ct),
                  preferred_element_type=jnp.float32)

    @pl.when(c == 0)
    def _init():
        o_ref[0, ...] = acc.astype(o_ref.dtype)

    @pl.when(c > 0)
    def _acc():
        o_ref[0, ...] = (o_ref[0, ...].astype(jnp.float32) + acc).astype(
            o_ref.dtype)


def gaussian_sa_pallas(
    A: jnp.ndarray,
    seeds: jnp.ndarray,
    m: int,
    *,
    chunk_cols: int = 512,
    interpret: bool = False,
    row_weights: jnp.ndarray | None = None,
    compute_dtype: str | None = None,
) -> jnp.ndarray:
    """Fused generate-and-multiply Gaussian sketch: (B, m, d) from
    A (n, d) shared or (B, n, d) per-problem; seeds (B,) uint32.

    Grid (B, n/chunk): each cell generates its (m, chunk) S tile from the
    counter hash in VMEM and contracts it with the A chunk on the MXU;
    the output block is revisited over the chunk axis (accumulator
    pattern). VMEM per step: m·chunk (S) + chunk·d (A) + m·d (acc); with
    m ≤ 1024, chunk = 512, d ≤ 512 this stays ≤ ~4 MiB. Entries match
    ``gaussian_sa_ref`` / ``gaussian_s_dense`` bit-for-bit (same counter
    hash); the contraction differs only in reduction order.

    ``row_weights`` (B, n) switches to the scaled kernel: the S tile is
    scaled by w^{1/2} in VMEM (one extra (1, chunk) block input per cell);
    W^{1/2}A never exists in HBM.

    ``compute_dtype`` (``kernels.precision``): ``"bf16"`` casts the S tile
    and A chunk to bfloat16 in-register for the MXU's bf16×bf16→fp32 mode
    (pass A already stored in bf16 to also halve the HBM stream — the cast
    composes, the one touch of A stays one touch); ``"int8"`` streams
    per-row int8 codes of A and folds the dequantization scales into the
    scaled kernel's per-column factor alongside any weights."""
    shared = A.ndim == 2
    n, d = A.shape[-2], A.shape[-1]
    B = seeds.shape[0]
    _check_caps(n, m)
    A, scale, ct, out_dtype = resolve_stream(A, B, row_weights, compute_dtype)
    chunk = max(_MICRO, (chunk_cols // _MICRO) * _MICRO)
    chunk = min(chunk, -(-n // _MICRO) * _MICRO)  # never pad past one chunk
    pad = (-n) % chunk
    if pad:
        A = jnp.pad(A, ((0, pad), (0, 0)) if shared
                    else ((0, 0), (0, pad), (0, 0)))
        if scale is not None:
            scale = jnp.pad(scale, ((0, 0), (0, pad)))
        n = n + pad
    grid = (B, n // chunk)
    a_spec = (
        pl.BlockSpec((chunk, d), lambda b, c: (c, 0))
        if shared
        else pl.BlockSpec((1, chunk, d), lambda b, c: (b, c, 0))
    )
    if scale is None:
        return pl.pallas_call(
            functools.partial(_gauss_sa_kernel, m=m, chunk=chunk, ct=ct),
            grid=grid,
            in_specs=[
                pl.BlockSpec((1,), lambda b, c: (b,)),
                a_spec,
            ],
            out_specs=pl.BlockSpec((1, m, d), lambda b, c: (b, 0, 0)),
            out_shape=jax.ShapeDtypeStruct((B, m, d), out_dtype),
            interpret=interpret,
        )(seeds.astype(jnp.uint32), A)
    return pl.pallas_call(
        functools.partial(_gauss_sa_kernel_scaled, m=m, chunk=chunk, ct=ct),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, c: (b,)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            a_spec,
        ],
        out_specs=pl.BlockSpec((1, m, d), lambda b, c: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, m, d), out_dtype),
        interpret=interpret,
    )(seeds.astype(jnp.uint32), scale.astype(jnp.float32), A)
