"""jit'd public wrappers for the Pallas kernels, with CPU fallbacks.

On TPU the kernels run compiled; on CPU (this container) they run in
``interpret=True`` mode, which executes the kernel body step-by-step for
correctness validation. ``use_pallas=None`` auto-selects by backend.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from . import ref
from .fwht import fwht_pallas
from .gaussian_gram import gaussian_sa_pallas, gaussian_sa_ref
from .precision import canonical_compute_dtype, contract_dtype
from .sjlt import fold_row_weights as sjlt_fold_row_weights
from .sjlt import sjlt_pallas, sjlt_pallas_batched

_FWHT_VMEM_MAX_N = 16_384  # n · 128 cols · 4 B ≈ 8 MiB


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit,
                   static_argnames=("use_pallas", "interpret",
                                    "compute_dtype"))
def fwht(x: jnp.ndarray, *, use_pallas: bool | None = None,
         interpret: bool | None = None,
         row_scale: jnp.ndarray | None = None,
         compute_dtype: str | None = None) -> jnp.ndarray:
    """Unnormalized FWHT along axis 0 (n power of two). ``row_scale`` (n,)
    computes H·diag(s)·x — fused into the kernel's VMEM tile on the Pallas
    path (SRHT signs and GLM w^{1/2} ride along for free).

    ``compute_dtype`` (``kernels.precision``): bf16/int8 modes run the
    butterfly passes in bfloat16 — the tile (and fused scale) is cast
    in-register, halving the transform's VMEM/HBM footprint; an int8 ``x``
    (quantized codes ≤ 127, exact in bf16) rides the same cast. The final
    Gram contraction downstream stays fp32 (the SRHT provider's einsum)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if canonical_compute_dtype(compute_dtype) != "fp32":
        ct = contract_dtype(compute_dtype)
        x = x.astype(ct)
        if row_scale is not None:
            row_scale = row_scale.astype(ct)
    n = x.shape[0]
    if not use_pallas:
        if row_scale is not None:
            x = x * row_scale[:, None].astype(x.dtype)
        return ref.fwht_ref(x)
    if n <= _FWHT_VMEM_MAX_N:
        return fwht_pallas(x, interpret=interpret, row_scale=row_scale)
    if row_scale is not None:
        x = x * row_scale[:, None].astype(x.dtype)
    return fwht_large(x, interpret=interpret)


def fwht_large(x: jnp.ndarray, *, interpret: bool = False) -> jnp.ndarray:
    """Two-pass radix-split FWHT for n > VMEM capacity:
    H_n = (H_a ⊗ I_b) (I_a ⊗ H_b) with n = a·b — pass 1 transforms the
    b axis of each (b, ·) panel; the transpose re-tiles; pass 2 transforms
    the a axis. Each pass is a VMEM-resident Pallas call."""
    n, d = x.shape
    lg = n.bit_length() - 1
    lb = min(lg, _FWHT_VMEM_MAX_N.bit_length() - 1)
    a, b = 1 << (lg - lb), 1 << lb
    # pass 1: I_a ⊗ H_b — reshape to (a, b, d), FWHT over b per slab
    y = x.reshape(a, b, d)
    y = jax.vmap(lambda s: fwht_pallas(s, interpret=interpret))(y)
    if a > 1:
        # pass 2: H_a ⊗ I_b — FWHT over the a axis: fold (b·d) into columns
        y = y.reshape(a, b * d)
        y = fwht_pallas(y, interpret=interpret)
        y = y.reshape(a, b, d)
    return y.reshape(n, d)


@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "interpret",
                                             "compute_dtype"))
def sjlt_apply(A: jnp.ndarray, rows: jnp.ndarray, signs: jnp.ndarray, m: int,
               *, use_pallas: bool | None = None,
               interpret: bool | None = None,
               row_weights: jnp.ndarray | None = None,
               compute_dtype: str | None = None) -> jnp.ndarray:
    """S @ A for an s=1 SJLT given per-row targets/signs. ``row_weights``
    (n,) computes S·W^{1/2}·A by folding w^{1/2} into the signs;
    ``compute_dtype`` selects the bf16 dispatch-matmul / int8-codes stream
    (``kernels.precision``) on both backends."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    signs = sjlt_fold_row_weights(signs, row_weights)
    if not use_pallas:
        return ref.sjlt_ref(A, rows, signs, m, compute_dtype=compute_dtype)
    return sjlt_pallas(A, rows, signs, m, interpret=interpret,
                       compute_dtype=compute_dtype)


@functools.partial(jax.jit, static_argnames=("m", "use_pallas", "interpret",
                                             "compute_dtype"))
def sjlt_apply_batched(A: jnp.ndarray, rows: jnp.ndarray, signs: jnp.ndarray,
                       m: int, *, use_pallas: bool | None = None,
                       interpret: bool | None = None,
                       row_weights: jnp.ndarray | None = None,
                       compute_dtype: str | None = None) -> jnp.ndarray:
    """Batch of SJLT sketches (B, m, d); A per-problem (B, n, d) or shared
    (n, d) across the batch (one grid cell per problem × row-block on TPU).
    ``row_weights`` (B, n) folds per-problem w^{1/2} into the sign stream
    — the weighted matrix W^{1/2}A never exists; ``compute_dtype`` rides
    the same slot (``kernels.precision``)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    signs = sjlt_fold_row_weights(signs, row_weights)
    if not use_pallas:
        return ref.sjlt_ref_batched(A, rows, signs, m,
                                    compute_dtype=compute_dtype)
    return sjlt_pallas_batched(A, rows, signs, m, interpret=interpret,
                               compute_dtype=compute_dtype)


@functools.partial(jax.jit, static_argnames=("m", "chunk_cols", "use_pallas",
                                             "interpret", "compute_dtype"))
def gaussian_sa(A: jnp.ndarray, seeds: jnp.ndarray, m: int, *,
                chunk_cols: int | None = None,
                use_pallas: bool | None = None,
                interpret: bool | None = None,
                row_weights: jnp.ndarray | None = None,
                compute_dtype: str | None = None) -> jnp.ndarray:
    """Streamed Gaussian sketch S @ A (B, m, d) without materializing S:
    A (n, d) shared or (B, n, d) per-problem, seeds (B,) uint32 — the fused
    generate-and-multiply Pallas kernel on TPU, the chunked ``lax.scan``
    oracle elsewhere. Sketch entries are identical on both paths (the same
    counter hash); only matmul reduction order differs.

    ``row_weights`` (B, n) computes S·W^{1/2}·A with w^{1/2} scaling the
    generated S tiles inside the stream (DESIGN.md §8) — neither S nor
    W^{1/2}A is ever materialized. ``compute_dtype`` selects the bf16 tile
    stream / int8-codes path (``kernels.precision``); both backends share
    the same dtype simulation, so results match per mode."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if interpret is None:
        interpret = not _on_tpu()
    if not use_pallas:
        return gaussian_sa_ref(A, seeds, m,
                               chunk_cols=chunk_cols or 2048,
                               row_weights=row_weights,
                               compute_dtype=compute_dtype)
    return gaussian_sa_pallas(A, seeds, m, chunk_cols=chunk_cols or 512,
                              interpret=interpret, row_weights=row_weights,
                              compute_dtype=compute_dtype)


def fwht_cols(X: jnp.ndarray, *, use_pallas: bool | None = None,
              interpret: bool | None = None,
              row_scale: jnp.ndarray | None = None,
              compute_dtype: str | None = None) -> jnp.ndarray:
    """FWHT along axis -2 of a batched (B, n, d) stack (n a power of two):
    one vmapped kernel call on TPU, the jnp butterfly elsewhere.
    ``row_scale`` (B, n) computes H·diag(s_b)·X_b per problem — the SRHT
    provider passes signs·w^{1/2} (× int8 dequantization scales) here so
    the sign-flip (and any GLM weighting) fuses into the transform's VMEM
    tile on the Pallas path. Non-fp32 ``compute_dtype`` returns the
    transformed stack in bf16 — the (B, n_pad, d) intermediate, the peak
    allocation of the SRHT provider, halves."""
    if row_scale is None:
        return jax.vmap(lambda x: fwht(x, use_pallas=use_pallas,
                                       interpret=interpret,
                                       compute_dtype=compute_dtype))(X)
    return jax.vmap(lambda x, s: fwht(x, use_pallas=use_pallas,
                                      interpret=interpret, row_scale=s,
                                      compute_dtype=compute_dtype)
                    )(X, row_scale)


def srht_sketch(A: jnp.ndarray, key: jax.Array, m: int, *,
                use_pallas: bool | None = None,
                interpret: bool | None = None,
                row_weights: jnp.ndarray | None = None,
                compute_dtype: str | None = None) -> jnp.ndarray:
    """Full SRHT sketch √(n_pad/m)·R·H·E·A using the FWHT kernel.
    ``row_weights`` (n,) sketches W^{1/2}A by folding w^{1/2} into the
    sign flip (one fused row scale, no weighted copy of A); non-fp32
    ``compute_dtype`` runs the butterflies in bf16 (int8 codes stream with
    dequantization scales folded into the same row scale) and returns the
    sampled rows in fp32.

    Row-sampling law: the m rows of H are sampled WITHOUT replacement
    (``jax.random.choice``, the classical SRHT — every row distinct while
    m ≤ n_pad), which has slightly better embedding constants at large
    m/n_pad. This deliberately differs from ``level_grams.SRHTProvider``,
    whose rows are i.i.d. uniform WITH replacement: the ladder needs a
    fixed row *stream* whose every prefix is a valid sample, and prefixes
    of a without-replacement draw are not exchangeable across levels.
    Both are unbiased (E[SᵀS] = I); tests/test_sharded.py pins the two
    laws."""
    name = canonical_compute_dtype(compute_dtype)
    n, d = A.shape
    n_pad = 1 << max(0, (n - 1).bit_length())
    k_sign, k_rows = jax.random.split(key)
    sign_dtype = A.dtype if name == "fp32" else jnp.float32
    signs = jax.random.rademacher(k_sign, (n,), dtype=sign_dtype)
    scale = signs if row_weights is None else signs * jnp.sqrt(
        row_weights).astype(sign_dtype)
    if name == "int8" and A.dtype != jnp.int8:
        from repro.dist.compress import quantize_rows

        A, a_scales = quantize_rows(A)
        scale = scale * a_scales
    X = A
    if n_pad != n:
        X = jnp.pad(X, ((0, n_pad - n), (0, 0)))
        scale = jnp.pad(scale, (0, n_pad - n))
    HX = fwht(X, use_pallas=use_pallas, interpret=interpret, row_scale=scale,
              compute_dtype=compute_dtype)
    rows = jax.random.choice(k_rows, n_pad, shape=(m,), replace=m > n_pad)
    out_dtype = A.dtype if name == "fp32" else jnp.float32
    return HX[rows].astype(out_dtype) * jnp.asarray(math.sqrt(1.0 / m),
                                                    out_dtype)
