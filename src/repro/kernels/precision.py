"""The compute-dtype axis of the one-touch sketch passes (DESIGN.md §10).

The adaptive ladder only needs the sketched Gram to be a *spectral
approximation* of the Hessian — the doubling controller absorbs
constant-factor sketch error by design, and preconditioner-reuse analyses
(arXiv 1911.02675, 2006.05874) show PCG iteration counts are insensitive
to modest perturbations of H_S. That headroom is what a reduced-precision
*stream* spends: the MXU-bound sketch→Gram contractions run at twice the
fp32 throughput in bf16 and the streamed operands halve (bf16) or quarter
(int8) their bandwidth, while everything the certificates depend on —
Gram accumulation, Cholesky factors, residuals, δ̃ — stays fp32.

Three named modes, plumbed end-to-end as a static string:

* ``"fp32"`` (default) — the existing bit-exact path; every wrapper with
  ``compute_dtype=None`` or ``"fp32"`` produces byte-identical results to
  the pre-dtype-axis code.
* ``"bf16"`` — sketch operands (generated S tiles, SJLT sign streams,
  FWHT butterfly tiles, A chunks) are cast to bfloat16 *in-register* and
  contracted with ``preferred_element_type=float32``: element products are
  bf16-rounded, accumulation is exact fp32 — the MXU's native mixed mode.
* ``"int8"`` — quantized-feature serving: A is quantized per ROW with
  symmetric int8 scales (Â = diag(s)·codes, |Â−A| ≤ s/2 entrywise), the
  int8 codes are what streams, and each family folds the dequantization
  scales into the per-row scale slot it already owns for GLM weights
  (generated-tile column scaling / sign stream / fused FWHT row scale) —
  dequantization happens in-register, never as an (n, d) float copy.
  Codes lie in [−127, 127] so their bf16 cast is exact and the contraction
  rides the same bf16×bf16→fp32 mode.

The canonical helpers here are shared by the kernels, their jnp oracles
and the level-Gram providers, so the tolerance model is identical on every
path.
"""

from __future__ import annotations

import jax.numpy as jnp

COMPUTE_DTYPES = ("fp32", "bf16", "int8")


def canonical_compute_dtype(compute_dtype: str | None) -> str:
    """Validate and canonicalize (None → "fp32")."""
    name = compute_dtype or "fp32"
    if name not in COMPUTE_DTYPES:
        raise ValueError(
            f"compute_dtype must be one of {COMPUTE_DTYPES}, "
            f"got {compute_dtype!r}")
    return name


def contract_dtype(compute_dtype: str | None):
    """The dtype sketch operands are cast to before the MXU contraction
    (accumulation is always fp32 via ``preferred_element_type``)."""
    return (jnp.float32 if canonical_compute_dtype(compute_dtype) == "fp32"
            else jnp.bfloat16)


def stream_itemsize(compute_dtype: str | None) -> int:
    """Bytes per streamed A element (the bandwidth axis of the win)."""
    return {"fp32": 4, "bf16": 2, "int8": 1}[
        canonical_compute_dtype(compute_dtype)]
