"""Pallas TPU kernels: fwht (SRHT core), sjlt (one-hot MXU sketch),
gaussian_gram (streaming fused Gaussian sketch with in-kernel PRNG)."""
