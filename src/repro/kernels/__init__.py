"""Pallas TPU kernels: fwht (SRHT core), sjlt (one-hot MXU sketch)."""
