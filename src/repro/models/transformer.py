"""The model stack: pattern-scan over heterogeneous layers, caches, logits.

Design points (see DESIGN.md):
* Layer heterogeneity is a repeating ``cfg.pattern`` of kinds; parameters
  are stacked per pattern *position* over the ``n_blocks`` repeats and the
  stack is traversed with ``lax.scan`` — HLO size is independent of depth.
* A remainder (n_layers % len(pattern)) is applied unrolled.
* Decode carries a cache pytree mirroring the block structure.
* Whisper (enc-dec) adds an encoder stack + cross-attention caches.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from . import layers as L
from .config import MOE_KINDS, WINDOWED_KINDS, ModelConfig


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    ks = jax.random.split(key, 6)
    d = cfg.d_model
    p = {"ln1": L.init_rms_norm(d), "ln2": L.init_rms_norm(d)}
    if kind in ("attn", "local", "enc"):
        p["attn"] = L.init_attention(ks[0], cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "dec":
        p["attn"] = L.init_attention(ks[0], cfg)
        p["cross"] = L.init_attention(ks[2], cfg, cross=True)
        p["ln_cross"] = L.init_rms_norm(d)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind in MOE_KINDS:
        p["attn"] = L.init_attention(ks[0], cfg)
        p["moe"] = L.init_moe(ks[1], cfg)
    elif kind == "rnn":
        p["rnn"] = L.init_rnn(ks[0], cfg)
        p["mlp"] = L.init_mlp(ks[1], cfg)
    elif kind == "rwkv":
        p["rwkv"] = L.init_rwkv(ks[0], cfg)
    else:
        raise ValueError(kind)
    return p


def init_params(key, cfg: ModelConfig, *, max_seq: int = 4096) -> dict:
    kE, kH, kB, kR, kEnc, kPos = jax.random.split(key, 6)
    d, V = cfg.d_model, cfg.vocab
    params: dict = {
        "embed": jax.random.normal(kE, (V, d), jnp.float32) / math.sqrt(d),
        "final_norm": L.init_rms_norm(d),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = jax.random.normal(kH, (d, V), jnp.float32) / math.sqrt(d)
    if cfg.pos_embedding == "learned":
        params["pos"] = jax.random.normal(kPos, (max_seq, d), jnp.float32) * 0.02

    # stacked pattern blocks
    blocks = {}
    for i, kind in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(kB, i), max(cfg.n_blocks, 1))
        if cfg.n_blocks > 0:
            blocks[f"p{i}_{kind}"] = jax.vmap(
                lambda k: init_layer(k, cfg, kind)
            )(keys)
    params["blocks"] = blocks
    # remainder layers, unrolled
    rem = {}
    for i in range(cfg.n_rem):
        kind = cfg.pattern[i]
        rem[f"r{i}_{kind}"] = init_layer(jax.random.fold_in(kR, i), cfg, kind)
    params["rem"] = rem

    # encoder stack (whisper)
    if cfg.n_enc_layers:
        keys = jax.random.split(kEnc, cfg.n_enc_layers)
        params["enc_blocks"] = jax.vmap(
            lambda k: init_layer(k, cfg, "enc")
        )(keys)
        params["enc_norm"] = L.init_rms_norm(d)
        params["enc_pos"] = (
            jax.random.normal(jax.random.fold_in(kPos, 1), (cfg.enc_seq, d), jnp.float32) * 0.02
        )
    return params


# ---------------------------------------------------------------------------
# Cache init
# ---------------------------------------------------------------------------

def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                     dtype=jnp.bfloat16) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.head_dim
    if kind in ("attn", "attn_moe", "enc"):
        S = max_seq
        return {"k": jnp.zeros((batch, S, KV, hd), dtype),
                "v": jnp.zeros((batch, S, KV, hd), dtype)}
    if kind in WINDOWED_KINDS:
        S = min(cfg.window, max_seq)
        return {"k": jnp.zeros((batch, S, KV, hd), dtype),
                "v": jnp.zeros((batch, S, KV, hd), dtype)}
    if kind == "dec":
        return {
            "k": jnp.zeros((batch, max_seq, KV, hd), dtype),
            "v": jnp.zeros((batch, max_seq, KV, hd), dtype),
            "ck": jnp.zeros((batch, cfg.enc_seq, KV, hd), dtype),
            "cv": jnp.zeros((batch, cfg.enc_seq, KV, hd), dtype),
        }
    if kind == "rnn":
        w = cfg.rnn_width_eff
        return {"h": jnp.zeros((batch, w), jnp.float32),
                "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dtype)}
    if kind == "rwkv":
        H, hd_r = cfg.n_rwkv_heads, cfg.rwkv_head_dim
        return {"S": jnp.zeros((batch, H, hd_r, hd_r), jnp.float32),
                "tm_x": jnp.zeros((batch, cfg.d_model), dtype),
                "cm_x": jnp.zeros((batch, cfg.d_model), dtype)}
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode cache pytree: stacked per pattern position + remainder."""
    def stack(tree, n):
        return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), tree)

    cache = {"blocks": {}, "rem": {}}
    for i, kind in enumerate(cfg.pattern):
        if cfg.n_blocks > 0:
            cache["blocks"][f"p{i}_{kind}"] = stack(
                init_layer_cache(cfg, kind, batch, max_seq, dtype), cfg.n_blocks
            )
    for i in range(cfg.n_rem):
        kind = cfg.pattern[i]
        cache["rem"][f"r{i}_{kind}"] = init_layer_cache(
            cfg, kind, batch, max_seq, dtype
        )
    return cache


# ---------------------------------------------------------------------------
# Layer application
# ---------------------------------------------------------------------------

def apply_layer(p, cfg: ModelConfig, kind: str, x, positions, cache=None,
                cache_pos=None, enc_out=None):
    """Pre-norm residual layer of the given kind. Returns (x, new_cache)."""
    if kind == "rwkv":
        return L.rwkv_block(p["rwkv"] | {"ln1": p["ln1"], "ln2": p["ln2"]},
                            cfg, x, cache)
    if kind == "rnn":
        h, new_cache = L.rnn_block(
            p["rnn"], cfg, L.rms_norm(p["ln1"], x, cfg.norm_eps), cache
        )
        x = x + h
        x = x + L.mlp(p["mlp"], cfg, L.rms_norm(p["ln2"], x, cfg.norm_eps))
        return x, new_cache

    # attention kinds
    h, new_cache = L.attention(
        p["attn"], cfg, L.rms_norm(p["ln1"], x, cfg.norm_eps), positions,
        kind=kind, cache=cache, cache_pos=cache_pos,
    )
    x = x + h
    if kind == "dec":
        if cache is not None:
            h = L.cross_attention_cached(
                p["cross"], cfg,
                L.rms_norm(p["ln_cross"], x, cfg.norm_eps),
                cache,
            )
        else:
            h, _ = L.attention(
                p["cross"], cfg,
                L.rms_norm(p["ln_cross"], x, cfg.norm_eps), positions,
                kind=kind, enc_out=enc_out,
            )
        x = x + h
    y = L.rms_norm(p["ln2"], x, cfg.norm_eps)
    if kind in MOE_KINDS:
        x = x + L.moe(p["moe"], cfg, y)
    else:
        x = x + L.mlp(p["mlp"], cfg, y)
    if kind == "dec" and new_cache is not None:
        new_cache = new_cache | {"ck": cache["ck"], "cv": cache["cv"]}
    return x, new_cache


# ---------------------------------------------------------------------------
# Full forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, compute_dtype):
    if cfg.onehot_embed:
        # One-hot matmul lookup: with a vocab-sharded table the gather
        # forces GSPMD into "involuntary full rematerialization" (an
        # all-gather of the whole table); the one-hot contraction keeps the
        # vocab dim sharded and reduces with one psum of (B,S,D).
        oh = jax.nn.one_hot(tokens, cfg.vocab, dtype=compute_dtype)
        x = oh @ params["embed"].astype(compute_dtype)
    else:
        x = jnp.take(params["embed"], tokens, axis=0).astype(compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), compute_dtype)
    return x


def encode(params, cfg: ModelConfig, enc_feats, compute_dtype=jnp.bfloat16):
    """Whisper encoder: enc_feats (B, enc_seq, d_model) — the stub frontend
    supplies precomputed frame embeddings per the brief."""
    x = enc_feats.astype(compute_dtype)
    x = x + params["enc_pos"][None, : x.shape[1]].astype(compute_dtype)
    positions = jnp.arange(x.shape[1])

    def body(x, blk):
        x, _ = apply_layer(blk, cfg, "enc", x, positions)
        return x, None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(params["enc_norm"], x, cfg.norm_eps)


def build_cross_cache(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross K/V from encoder output."""
    def kv(blk):
        p = blk["cross"]
        dt = enc_out.dtype
        k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"].astype(dt))
        v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"].astype(dt))
        return {"ck": k, "cv": v}

    out = {"blocks": {}, "rem": {}}
    for name, blk in params["blocks"].items():
        if name.split("_", 1)[1] == "dec":
            out["blocks"][name] = jax.vmap(kv)(blk)
    for name, blk in params["rem"].items():
        if name.split("_", 1)[1] == "dec":
            out["rem"][name] = kv(blk)
    return out


def forward(
    params,
    cfg: ModelConfig,
    tokens: jnp.ndarray,              # (B, S) int32
    *,
    cache=None,
    cache_pos=None,                   # scalar int32 (decode only)
    enc_feats=None,                   # (B, enc_seq, d) whisper train/prefill
    compute_dtype=jnp.bfloat16,
    remat: bool = False,
    scan_unroll: bool = False,        # analysis builds: XLA cost_analysis
                                      # counts loop bodies ONCE, so the
                                      # roofline sweep unrolls the layer scan
):
    """Returns (logits f32 (B, S, V), new_cache)."""
    B, S = tokens.shape
    x = embed_tokens(params, cfg, tokens, compute_dtype)

    if cache is not None:
        positions = jnp.broadcast_to(
            jnp.asarray(cache_pos, jnp.int32)[None, None]
            + jnp.arange(S, dtype=jnp.int32)[None, :],
            (B, S),
        )
    else:
        positions = jnp.arange(S)
    if cfg.pos_embedding == "learned":
        if cache is not None:
            pos_e = jax.lax.dynamic_slice_in_dim(params["pos"], cache_pos, S)
        else:
            pos_e = params["pos"][:S]
        x = x + pos_e[None].astype(compute_dtype)

    enc_out = None
    if cfg.n_enc_layers and enc_feats is not None:
        enc_out = encode(params, cfg, enc_feats, compute_dtype)

    new_cache = {"blocks": {}, "rem": {}} if cache is not None else None

    # --- scanned pattern blocks ---
    for i, kind in enumerate(cfg.pattern):
        name = f"p{i}_{kind}"
        if cfg.n_blocks == 0:
            continue
        blk_params = params["blocks"][name]
        blk_cache = cache["blocks"][name] if cache is not None else None

        def body(x, xs, kind=kind):
            bp, bc = xs
            fn = apply_layer
            if remat:
                fn = jax.checkpoint(apply_layer, static_argnums=(1, 2))
            x, nc = fn(bp, cfg, kind, x, positions, bc, cache_pos, enc_out)
            return x, nc

        unroll = cfg.n_blocks if scan_unroll else 1
        if cache is not None:
            x, ncache = jax.lax.scan(
                body, x, (blk_params, blk_cache), unroll=unroll
            )
            new_cache["blocks"][name] = ncache
        else:
            x, _ = jax.lax.scan(body, x, (blk_params, None), unroll=unroll)

    # --- remainder layers (unrolled) ---
    for i in range(cfg.n_rem):
        kind = cfg.pattern[i]
        name = f"r{i}_{kind}"
        rp = params["rem"][name]
        rc = cache["rem"][name] if cache is not None else None
        fn = apply_layer
        if remat and cache is None:
            fn = jax.checkpoint(apply_layer, static_argnums=(1, 2))
        x, nc = fn(rp, cfg, kind, x, positions, rc, cache_pos, enc_out)
        if cache is not None:
            new_cache["rem"][name] = nc

    x = L.rms_norm(params["final_norm"], x, cfg.norm_eps)
    head = (
        params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    ).astype(compute_dtype)
    logits = (x @ head).astype(jnp.float32)
    logits = L.softcap(logits, cfg.final_softcap)
    return logits, new_cache
