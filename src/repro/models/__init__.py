"""Architecture zoo: pure-functional JAX models for the 10 assigned archs."""

from .config import ModelConfig
from .transformer import (
    build_cross_cache,
    encode,
    forward,
    init_cache,
    init_params,
)

__all__ = [
    "ModelConfig",
    "forward",
    "init_params",
    "init_cache",
    "encode",
    "build_cross_cache",
]
