"""Layer implementations for the architecture zoo (pure functional JAX).

Conventions:
* params are nested dicts of jnp arrays; init_* returns params, apply takes
  (params, cfg, x, ...) and never mutates.
* activations x are (B, S, D). Decode passes S=1 plus a cache.
* compute happens in ``x.dtype`` (callers cast to bf16); norms/softmax in f32.
* caches are dicts per layer kind; see each block's docstring.
"""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig, WINDOWED_KINDS


# ---------------------------------------------------------------------------
# Norms, embeddings, positional encodings
# ---------------------------------------------------------------------------

def init_rms_norm(d: int) -> dict:
    return {"scale": jnp.zeros((d,), jnp.float32)}


def rms_norm(p: dict, x: jnp.ndarray, eps: float) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * (1.0 + p["scale"])
    return y.astype(dtype)


def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotary embedding. x: (B, S, H, hd); positions: (B, S) or (S,)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return cap * jnp.tanh(logits / cap)
    return logits


# ---------------------------------------------------------------------------
# Attention (GQA; full-causal / sliding-window / bidirectional / cross)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    scale_q = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, H, hd), jnp.float32) * scale_q,
        "wk": jax.random.normal(ks[1], (d, KV, hd), jnp.float32) * scale_q,
        "wv": jax.random.normal(ks[2], (d, KV, hd), jnp.float32) * scale_q,
        "wo": jax.random.normal(ks[3], (H, hd, d), jnp.float32)
        * (1.0 / math.sqrt(H * hd)),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H, hd), jnp.float32)
        p["bk"] = jnp.zeros((KV, hd), jnp.float32)
        p["bv"] = jnp.zeros((KV, hd), jnp.float32)
    return p


def _qkv(p, cfg, x, x_kv=None):
    dt = x.dtype
    x_kv = x if x_kv is None else x_kv
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x_kv, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x_kv, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def _attend(q, k, v, cfg: ModelConfig, mask_bias) -> jnp.ndarray:
    """q: (B,Sq,H,hd); k/v: (B,Skv,KV,hd); mask_bias: (B or 1, Sq, Skv)."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    logits = jnp.einsum("bqkgd,btkd->bkgqt", qg, k) / math.sqrt(hd)
    logits = softcap(logits.astype(jnp.float32), cfg.attn_softcap)
    logits = logits + mask_bias[:, None, None, :, :]
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkd->bqkgd", w, v)
    return out.reshape(B, Sq, H, hd)


def causal_mask_bias(
    sq: int, skv: int, *, offset: int = 0, window: int = 0,
    bidirectional: bool = False, dtype=jnp.float32,
) -> jnp.ndarray:
    """Additive (1, Sq, Skv) mask. offset = absolute position of query 0
    minus position of key 0 (for caches). window>0 = sliding window."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    ok = jnp.ones((sq, skv), bool) if bidirectional else (kpos <= qpos)
    if window and window > 0:
        ok = ok & (kpos > qpos - window)
    neg = jnp.asarray(jnp.finfo(jnp.float32).min, jnp.float32)
    return jnp.where(ok, 0.0, neg)[None].astype(dtype)


def attention(
    p: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    *,
    kind: str,
    cache: Optional[dict] = None,
    cache_pos: Optional[jnp.ndarray] = None,
    enc_out: Optional[jnp.ndarray] = None,
):
    """Self- or cross-attention. Returns (out, new_cache).

    cache (self-attn): {"k","v"}: (B, S_cache, KV, hd); cache_pos: scalar
    int32, number of valid cached tokens (also the absolute position of the
    incoming token for full caches; for windowed caches the cache is a ring
    buffer and cache_pos is the absolute position).
    """
    window = cfg.window if kind in WINDOWED_KINDS else 0
    bidir = kind == "enc"
    if enc_out is not None:
        # cross attention (no mask, no rope)
        q, k, v = _qkv(p, cfg, x, x_kv=enc_out)
        bias = jnp.zeros((1, x.shape[1], enc_out.shape[1]), jnp.float32)
        out = _attend(q, k, v, cfg, bias)
        dt = x.dtype
        return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt)), None

    q, k, v = _qkv(p, cfg, x)
    if cfg.pos_embedding == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    if cfg.seq_shard_attn and x.shape[1] > 1:
        # Sequence-parallel attention: shard queries over 'model' (kv stays
        # replicated) — softmax over keys remains device-local; used when
        # n_heads % TP ≠ 0 would otherwise replicate the whole attention.
        from jax.sharding import PartitionSpec as _P
        q = jax.lax.with_sharding_constraint(
            q, _P(None, "model", None, None))

    new_cache = None
    if cache is not None:
        S_cache = cache["k"].shape[1]
        Sq = x.shape[1]
        neg = jnp.asarray(jnp.finfo(jnp.float32).min, jnp.float32)
        if window and window > 0 and S_cache == window:
            if Sq == 1:
                # Decode into a ring buffer: slot i holds the most recent
                # absolute position p ≤ cache_pos with p ≡ i (mod window).
                slot = cache_pos % window
                ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
                cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
                kabs = cache_pos - ((slot - jnp.arange(window)) % window)
                bias = jnp.where(kabs >= 0, 0.0, neg)[None, None, :]
                out = _attend(q, ck, cv, cfg, bias)
            else:
                # Prefill from an empty cache (cache_pos = 0, Sq ≥ window):
                # attend directly, then store the last `window` tokens at
                # their ring slots (slot of abs pos p is p % window).
                bias = causal_mask_bias(Sq, Sq, window=window)
                out = _attend(q, k, v, cfg, bias)
                ck = jnp.roll(k[:, -window:], Sq % window, axis=1)
                cv = jnp.roll(v[:, -window:], Sq % window, axis=1)
            new_cache = {"k": ck, "v": cv}
        else:
            ck = jax.lax.dynamic_update_slice(cache["k"], k, (0, cache_pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v, (0, cache_pos, 0, 0))
            kpos = jnp.arange(S_cache)[None, :]
            qpos = cache_pos + jnp.arange(Sq)[:, None]
            ok = kpos <= qpos
            if window and window > 0:
                ok = ok & (kpos > qpos - window)
            bias = jnp.where(ok, 0.0, neg)[None]
            out = _attend(q, ck, cv, cfg, bias)
            new_cache = {"k": ck, "v": cv}
    else:
        bias = causal_mask_bias(
            x.shape[1], x.shape[1], window=window, bidirectional=bidir
        )
        out = _attend(q, k, v, cfg, bias)

    dt = x.dtype
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return y, new_cache


def cross_attention_cached(p, cfg, x, cache):
    """Decode-time cross-attention against precomputed enc K/V."""
    dt = x.dtype
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    bias = jnp.zeros((1, x.shape[1], cache["ck"].shape[1]), jnp.float32)
    out = _attend(q, cache["ck"], cache["cv"], cfg, bias)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU or GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "wi": jax.random.normal(ks[0], (d, f), jnp.float32) * s_in,
        "wo": jax.random.normal(ks[2], (f, d), jnp.float32) * s_out,
    }
    if cfg.mlp_act == "swiglu":
        p["wg"] = jax.random.normal(ks[1], (d, f), jnp.float32) * s_in
    return p


def mlp(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    dt = x.dtype
    h = x @ p["wi"].astype(dt)
    if "wg" in p:
        h = jax.nn.silu(x @ p["wg"].astype(dt)) * h
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(dt)


# ---------------------------------------------------------------------------
# MoE MLP — group-capacity dispatch via one-hot einsums (Mesh-TF style).
# Groups bound the dispatch tensor to O(T_g² · k · cf); group size 512.
# ---------------------------------------------------------------------------

MOE_GROUP = 512


def init_moe(key, cfg: ModelConfig) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_expert_eff
    ks = jax.random.split(key, 5)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(f)
    p = {
        "router": jax.random.normal(ks[0], (d, E), jnp.float32) * s_in,
        "wi": jax.random.normal(ks[1], (E, d, f), jnp.float32) * s_in,
        "wg": jax.random.normal(ks[2], (E, d, f), jnp.float32) * s_in,
        "wo": jax.random.normal(ks[3], (E, f, d), jnp.float32) * s_out,
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=cfg.n_shared_experts * f)
    return p


def moe(p: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    """x: (B, S, D). Top-k routing with per-group capacity; dropped tokens
    pass through the residual only (standard capacity-drop semantics)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    dt = x.dtype
    g_sz = min(MOE_GROUP, S)
    G = (B * S) // g_sz
    xg = x.reshape(G, g_sz, D)
    C = max(1, int(math.ceil(k * g_sz * cfg.capacity_factor / E)))

    logits = (xg.astype(jnp.float32)) @ p["router"]  # (G, T, E) in f32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)  # (G, T, k)
    # renormalize the top-k gates (mixtral/qwen practice)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # Positions within each expert queue, per top-k slot, priority by k-slot.
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,T,k,E)
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, k * g_sz, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, k·T, E) position per entry
    pos = pos.reshape(G, k, g_sz, E).transpose(0, 2, 1, 3)  # (G,T,k,E)
    in_cap = (pos < C).astype(jnp.float32) * onehot
    pos_cap = jnp.clip(jnp.sum(pos * onehot, axis=-1), 0, C - 1)  # (G,T,k)
    slot_oh = jax.nn.one_hot(pos_cap, C, dtype=jnp.float32)  # (G,T,k,C)

    # dispatch/combine: (G, T, E, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", in_cap, slot_oh)
    combine = jnp.einsum(
        "gtke,gtkc,gtk->gtec", in_cap, slot_oh, gate_vals
    )

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(dt), xg)  # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", xe, p["wi"].astype(dt))
    hg = jnp.einsum("gecd,edf->gecf", xe, p["wg"].astype(dt))
    h = jax.nn.silu(hg) * h
    ye = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dt))
    y = jnp.einsum("gecd,gtec->gtd", ye, combine.astype(dt))
    y = y.reshape(B, S, D)
    if "shared" in p:
        y = y + mlp(p["shared"], cfg, x)
    return y


# ---------------------------------------------------------------------------
# Griffin / RecurrentGemma RG-LRU recurrent block
# cache: {"h": (B, W), "conv": (B, conv_width-1, W)}
# ---------------------------------------------------------------------------

RG_LRU_HEADS = 16  # Griffin uses block-diagonal gate matrices


def init_rnn(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.rnn_width_eff
    nh = RG_LRU_HEADS if w % RG_LRU_HEADS == 0 else 1
    wh = w // nh
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    # a_param initialized so decay a ≈ 0.9–0.999 (Griffin init)
    c = 8.0
    lam = jax.random.uniform(ks[4], (w,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1((-jnp.log(lam)) / c))  # softplus⁻¹
    return {
        "wx": jax.random.normal(ks[0], (d, w), jnp.float32) * s,
        "wgate": jax.random.normal(ks[1], (d, w), jnp.float32) * s,
        "conv": jax.random.normal(ks[2], (cfg.conv_width, w), jnp.float32)
        * (1.0 / math.sqrt(cfg.conv_width)),
        # block-diagonal input/recurrence gates (Griffin): (heads, wh, wh)
        "w_in_gate": jax.random.normal(ks[3], (nh, wh, wh), jnp.float32)
        * (1.0 / math.sqrt(wh)),
        "w_a_gate": jax.random.normal(ks[5], (nh, wh, wh), jnp.float32)
        * (1.0 / math.sqrt(wh)),
        "a_param": a_param,
        "wo": jax.random.normal(ks[6], (w, d), jnp.float32)
        * (1.0 / math.sqrt(w)),
    }


def _block_diag_gate(wg, u):
    """u: (B,S,W) → sigmoid(u @ blockdiag(wg)): wg (nh, wh, wh)."""
    B, S, W = u.shape
    nh, wh, _ = wg.shape
    uh = u.reshape(B, S, nh, wh)
    return jax.nn.sigmoid(
        jnp.einsum("bshw,hwv->bshv", uh, wg.astype(u.dtype)).reshape(B, S, W)
    )


def _rg_lru(p, u: jnp.ndarray, h0: jnp.ndarray):
    """RG-LRU over a sequence. u: (B, S, W); h0: (B, W). Returns (y, h_T)."""
    c = 8.0
    r_gate = _block_diag_gate(p["w_a_gate"], u)
    i_gate = _block_diag_gate(p["w_in_gate"], u)
    log_a = -c * jax.nn.softplus(p["a_param"]).astype(jnp.float32) * r_gate.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (u * i_gate).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * gated

    def combine(l, r):
        a1, b1 = l
        a2, b2 = r
        return a1 * a2, a2 * b1 + b2

    # prepend carry as step 0: h_t = a_t h_{t-1} + b_t with h_{-1} = h0
    a_all = jnp.concatenate([jnp.ones_like(a[:, :1]), a], axis=1)
    b_all = jnp.concatenate([h0.astype(jnp.float32)[:, None], b], axis=1)
    _, hs = jax.lax.associative_scan(combine, (a_all, b_all), axis=1)
    y = hs[:, 1:]
    return y.astype(u.dtype), y[:, -1].astype(jnp.float32)


def rnn_block(p, cfg: ModelConfig, x: jnp.ndarray, cache=None):
    """Griffin recurrent block. Returns (out, new_cache)."""
    dt = x.dtype
    B, S, _ = x.shape
    w = cfg.rnn_width_eff
    u = x @ p["wx"].astype(dt)          # (B,S,W)
    gate = jax.nn.gelu(x @ p["wgate"].astype(dt))
    cw = cfg.conv_width
    if cache is not None:
        hist = jnp.concatenate([cache["conv"].astype(dt), u], axis=1)
        h0 = cache["h"]
    else:
        hist = jnp.pad(u, ((0, 0), (cw - 1, 0), (0, 0)))
        h0 = jnp.zeros((B, w), jnp.float32)
    conv = sum(
        hist[:, i : i + S] * p["conv"][i].astype(dt) for i in range(cw)
    )
    y, h_T = _rg_lru(p, conv, h0)
    out = (y * gate) @ p["wo"].astype(dt)
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_T, "conv": hist[:, -(cw - 1):].astype(cache["conv"].dtype)}
    return out, new_cache


# ---------------------------------------------------------------------------
# RWKV-6 (Finch) block: time-mix with data-dependent decay + channel-mix.
# cache: {"S": (B, H, hd, hd), "tm_x": (B, D), "cm_x": (B, D)}
# ---------------------------------------------------------------------------

def init_rwkv(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    r = cfg.rwkv_lora_r
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim
    ks = jax.random.split(key, 12)
    s = 1.0 / math.sqrt(d)
    return {
        # time-mix projections
        "wr": jax.random.normal(ks[0], (d, d), jnp.float32) * s,
        "wk": jax.random.normal(ks[1], (d, d), jnp.float32) * s,
        "wv": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
        "wg": jax.random.normal(ks[3], (d, d), jnp.float32) * s,
        "wo_tm": jax.random.normal(ks[4], (d, d), jnp.float32) * s,
        # token-shift interpolation: static μ per stream + shared lora
        "mu": jax.random.uniform(ks[5], (5, d), jnp.float32),  # r,k,v,g,w
        "mu_lora_a": jax.random.normal(ks[6], (d, r), jnp.float32) * s,
        "mu_lora_b": jax.random.normal(ks[7], (r, 5, d), jnp.float32)
        * (1.0 / math.sqrt(r)),
        # data-dependent decay lora
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w_lora_a": jax.random.normal(ks[8], (d, r), jnp.float32) * s,
        "w_lora_b": jax.random.normal(ks[9], (r, d), jnp.float32)
        * (1.0 / math.sqrt(r)),
        "u": jax.random.normal(ks[10], (H, hd), jnp.float32) * 0.1,
        "ln_x": init_rms_norm(d),
        # channel-mix
        "cm_mu": jax.random.uniform(ks[11], (2, d), jnp.float32),
        "cm_wk": jax.random.normal(ks[0], (d, cfg.d_ff), jnp.float32) * s,
        "cm_wv": jax.random.normal(ks[1], (cfg.d_ff, d), jnp.float32)
        * (1.0 / math.sqrt(cfg.d_ff)),
        "cm_wr": jax.random.normal(ks[2], (d, d), jnp.float32) * s,
    }


def _wkv_scan(r, k, v, w, u, S0):
    """RWKV-6 recurrence.  r,k,w: (B,T,H,hd); v: (B,T,H,hd); S0: (B,H,hd,hd).
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ ;  y_t = S_{t-1}ᵀ r_t + (rᵀ(u⊙k)) v.
    Returns (y: (B,T,H,hd), S_T)."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp  # (B,H,hd)
        y = jnp.einsum("bhij,bhi->bhj", S, r_t) + jnp.einsum(
            "bhi,bhi,bhj->bhj", r_t, u[None] * k_t, v_t
        )
        S = w_t[..., None] * S + jnp.einsum("bhi,bhj->bhij", k_t, v_t)
        return S, y

    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (r, k, v, w))
    S_T, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_T


def rwkv_block(p, cfg: ModelConfig, x_raw: jnp.ndarray, cache=None):
    """Full RWKV-6 layer (time-mix + channel-mix), with its own pre-norms
    (token-shift operates on the *normed* stream, so the norms live here).
    p must contain "ln1"/"ln2". Returns (x_new, cache)."""
    dt = x_raw.dtype
    B, T, D = x_raw.shape
    H, hd = cfg.n_rwkv_heads, cfg.rwkv_head_dim

    # ---- time mix ----
    x = rms_norm(p["ln1"], x_raw, cfg.norm_eps)
    if cache is not None:
        first = cache["tm_x"].astype(dt)[:, None]
        prev = first if T == 1 else jnp.concatenate([first, x[:, :-1]], axis=1)
    else:
        prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx = prev - x
    # data-dependent interpolation (5 streams: r,k,v,g,w)
    lora = jnp.einsum("btd,dr->btr", x + dx * p["mu"][4].astype(dt), p["mu_lora_a"].astype(dt))
    mix = p["mu"].astype(dt)[None, None] + jnp.einsum(
        "btr,rsd->btsd", jnp.tanh(lora), p["mu_lora_b"].astype(dt)
    )  # (B,T,5,D)
    xr, xk, xv, xg, xw = (x + dx * mix[:, :, i] for i in range(5))

    r = (xr @ p["wr"].astype(dt)).reshape(B, T, H, hd)
    k = (xk @ p["wk"].astype(dt)).reshape(B, T, H, hd)
    v = (xv @ p["wv"].astype(dt)).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ p["wg"].astype(dt))
    wlog = p["w0"].astype(jnp.float32) + jnp.einsum(
        "btr,rd->btd",
        jnp.tanh(jnp.einsum("btd,dr->btr", xw, p["w_lora_a"].astype(dt))).astype(jnp.float32),
        p["w_lora_b"],
    )
    w = jnp.exp(-jnp.exp(wlog)).reshape(B, T, H, hd).astype(jnp.float32)

    S0 = (
        cache["S"] if cache is not None
        else jnp.zeros((B, H, hd, hd), jnp.float32)
    )
    y, S_T = _wkv_scan(
        r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
        w, p["u"], S0,
    )
    y = rms_norm(p["ln_x"], y.reshape(B, T, D).astype(dt), cfg.norm_eps)
    tm_out = (y * g) @ p["wo_tm"].astype(dt)

    # ---- channel mix ----
    x_mid = x_raw + tm_out
    x2 = rms_norm(p["ln2"], x_mid, cfg.norm_eps)
    if cache is not None:
        first2 = cache["cm_x"].astype(dt)[:, None]
        prev2 = first2 if T == 1 else jnp.concatenate([first2, x2[:, :-1]], axis=1)
    else:
        prev2 = jnp.pad(x2, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    dx2 = prev2 - x2
    xk2 = x2 + dx2 * p["cm_mu"][0].astype(dt)
    xr2 = x2 + dx2 * p["cm_mu"][1].astype(dt)
    kk = jnp.square(jax.nn.relu(xk2 @ p["cm_wk"].astype(dt)))
    cm_out = jax.nn.sigmoid(xr2 @ p["cm_wr"].astype(dt)) * (
        kk @ p["cm_wv"].astype(dt)
    )

    new_cache = None
    if cache is not None:
        new_cache = {
            "S": S_T,
            "tm_x": x[:, -1].astype(cache["tm_x"].dtype),
            "cm_x": x2[:, -1].astype(cache["cm_x"].dtype),
        }
    return x_mid + cm_out, new_cache
