"""Model configuration for the assigned architecture zoo.

One ``ModelConfig`` describes any of the 10 assigned LM-family backbones.
Layer heterogeneity (gemma2 local/global alternation, recurrentgemma's
2-recurrent:1-attention pattern, …) is expressed as a repeating ``pattern``
of layer *kinds*; the transformer stacks parameters per pattern position and
scans over pattern repeats, keeping HLO size independent of depth.

Layer kinds:
  "attn"      full causal GQA attention + dense MLP
  "local"     sliding-window causal attention + dense MLP
  "swa_moe"   sliding-window attention + MoE MLP         (mixtral)
  "attn_moe"  full attention + MoE MLP (+ shared experts) (qwen2-moe)
  "rnn"       Griffin/RecurrentGemma RG-LRU recurrent block + dense MLP
  "rwkv"      RWKV-6 time-mix + channel-mix block
  "enc"       bidirectional attention + dense MLP (whisper encoder)
  "dec"       causal self-attn + cross-attn + dense MLP (whisper decoder)
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

ATTN_KINDS = ("attn", "local", "swa_moe", "attn_moe", "enc", "dec")
MOE_KINDS = ("swa_moe", "attn_moe")
WINDOWED_KINDS = ("local", "swa_moe")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    pattern: Tuple[str, ...] = ("attn",)

    # attention
    rope_theta: float = 10_000.0
    window: int = 4096           # for windowed kinds
    attn_softcap: float = 0.0    # 0 = off (gemma2: 50)
    final_softcap: float = 0.0   # 0 = off (gemma2: 30)
    qkv_bias: bool = False
    pos_embedding: str = "rope"  # "rope" | "learned" (whisper)

    # embeddings / head
    tie_embeddings: bool = False
    embed_scale: bool = False    # gemma family scales embeds by √d_model
    onehot_embed: bool = False   # lookup as one-hot matmul: SPMD-friendly
                                 # when the table is vocab-sharded (§Perf)
    seq_shard_attn: bool = False # sequence-parallel attention over 'model'
                                 # for archs whose heads don't divide the TP
                                 # axis (q seq-sharded, kv replicated; §Perf)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0            # per-expert hidden size (= d_ff if 0)
    capacity_factor: float = 1.25

    # recurrent (Griffin RG-LRU)
    rnn_width: int = 0           # 0 → d_model
    conv_width: int = 4

    # rwkv
    rwkv_head_dim: int = 64
    rwkv_lora_r: int = 64        # rank of the data-dependent decay/mix LoRAs

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 1500          # whisper: 30 s of audio → 1500 frames

    # mlp / norm
    mlp_act: str = "swiglu"      # "swiglu" | "gelu"
    norm_eps: float = 1e-6

    # long-context capability: archs whose decode state is bounded
    # (recurrent state or windowed cache) can run the long_500k shape.
    supports_long_context: bool = False
    # encoder-only models have no decode step (none assigned, all have one)
    has_decoder: bool = True

    # ------------------------------------------------------------------
    @property
    def n_blocks(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_rem(self) -> int:
        return self.n_layers % len(self.pattern)

    @property
    def d_expert_eff(self) -> int:
        return self.d_expert or self.d_ff

    @property
    def rnn_width_eff(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def n_rwkv_heads(self) -> int:
        return self.d_model // self.rwkv_head_dim

    def param_count(self) -> int:
        """Total parameters (for MODEL_FLOPS and memory estimates)."""
        return sum(_kind_params(self, k) for k in self.layer_kinds()) + (
            self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
            + self.d_model  # final norm
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        total = 0
        for k in self.layer_kinds():
            if k in MOE_KINDS:
                attn = _attn_params(self)
                ffn1 = 3 * self.d_model * self.d_expert_eff
                total += attn + ffn1 * (self.top_k + self.n_shared_experts)
                total += self.d_model * self.n_experts  # router
                total += 2 * self.d_model
            else:
                total += _kind_params(self, k)
        total += self.vocab * self.d_model * (1 if self.tie_embeddings else 2)
        total += self.d_model
        return total

    def layer_kinds(self) -> Tuple[str, ...]:
        """The full depth-ordered list of layer kinds (decoder side)."""
        return self.pattern * self.n_blocks + self.pattern[: self.n_rem]

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        pat = self.pattern
        n_layers = max(len(pat) * 2, 2)
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(self.n_kv_heads, heads))
        hd = 16
        d_model = heads * hd
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads,
            n_kv_heads=kv,
            head_dim=hd,
            d_ff=4 * d_model,
            vocab=512,
            window=32,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            n_shared_experts=min(self.n_shared_experts, 1),
            d_expert=2 * d_model if self.d_expert else 0,
            rnn_width=d_model if self.rnn_width else 0,
            rwkv_head_dim=16,
            rwkv_lora_r=8,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_seq=16 if self.n_enc_layers else self.enc_seq,
        )


def _attn_params(cfg: ModelConfig) -> int:
    q = cfg.d_model * cfg.n_heads * cfg.head_dim
    kv = 2 * cfg.d_model * cfg.n_kv_heads * cfg.head_dim
    o = cfg.n_heads * cfg.head_dim * cfg.d_model
    return q + kv + o


def _mlp_params(cfg: ModelConfig, d_ff: int) -> int:
    mult = 3 if cfg.mlp_act == "swiglu" else 2
    return mult * cfg.d_model * d_ff


def _kind_params(cfg: ModelConfig, kind: str) -> int:
    d = cfg.d_model
    norms = 2 * d
    if kind in ("attn", "local", "enc"):
        return _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + norms
    if kind == "dec":
        return 2 * _attn_params(cfg) + _mlp_params(cfg, cfg.d_ff) + 3 * d
    if kind in MOE_KINDS:
        ffn_all = 3 * d * cfg.d_expert_eff * (cfg.n_experts + cfg.n_shared_experts)
        return _attn_params(cfg) + ffn_all + d * cfg.n_experts + norms
    if kind == "rnn":
        w = cfg.rnn_width_eff
        nh = 16 if w % 16 == 0 else 1
        # in/gate projections, conv, block-diag RG-LRU gates, decay, out
        rec = 2 * d * w + cfg.conv_width * w + 2 * w * (w // nh) + w * d + w
        return rec + _mlp_params(cfg, cfg.d_ff) + norms
    if kind == "rwkv":
        r = cfg.rwkv_lora_r
        tm = 4 * d * d + d * d  # r,k,v,g,o  (w is per-channel via lora)
        loras = 5 * (d * r + r * d) + d * r * 2  # mix loras + decay lora
        cm = 2 * d * cfg.d_ff  # channel-mix (k, v) — rwkv6 uses ~3.5x
        return tm + loras + cm + norms
    raise ValueError(kind)
