"""Node-failure resilience: elastic re-meshing, straggler detection,
preemption handling. The policies are framework-level (orchestrator hooks on
a real pod); the mechanisms are implemented and unit-tested here.
"""

from __future__ import annotations

import dataclasses
import signal
import statistics
import time
from typing import Callable, Optional


# jax ≥ 0.5 exposes AxisType and takes AbstractMesh(axis_sizes, axis_names);
# 0.4.x has neither the enum nor that signature (AbstractMesh takes a
# ((name, size), ...) shape tuple). Normalize behind one constructor so
# planning code is version-independent.
from jax.sharding import AbstractMesh

try:  # jax ≥ 0.5
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None


def _abstract_mesh(shape: tuple[int, ...], names: tuple[str, ...]):
    if AxisType is not None:
        try:
            return AbstractMesh(
                shape, names, axis_types=tuple(AxisType.Auto for _ in names))
        except TypeError:  # pre-0.6 keyword variants
            return AbstractMesh(shape, names)
    return AbstractMesh(tuple(zip(names, shape)))


# ---------------------------------------------------------------------------
# Elastic scaling: rebuild mesh from live device count + reshard via ckpt
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ElasticPlan:
    n_devices: int
    mesh: "jax.sharding.AbstractMesh"
    per_device_batch: int
    num_microbatches: int


def plan_mesh_shape(n_devices: int) -> tuple[int, int]:
    """(data, model) for an arbitrary live-device count — prefers model=16,
    else the largest power-of-two divisor ≤ 16."""
    model = 1
    for cand in (16, 8, 4, 2):
        if n_devices % cand == 0:
            model = cand
            break
    return n_devices // model, model


def plan_elastic(global_batch: int, n_live_devices: int,
                 target_microbatch: int = 32) -> ElasticPlan:
    """Largest usable mesh for the live-device count + a batch plan that
    preserves the *global* batch (grad-equivalent training after restart).

    Planning uses an AbstractMesh (no device objects needed — callable from
    the controller before the new slice is up); ``launch.mesh
    .make_elastic_mesh`` realizes it against live devices at restart.
    Devices that don't fit the mesh shape are left idle (hot spares)."""
    data, model = plan_mesh_shape(n_live_devices)
    # the data axis must divide the global batch: shrink it to the largest
    # divisor ≤ data (excess devices idle as hot spares)
    while global_batch % data:
        data -= 1
    mesh = _abstract_mesh((data, model), ("data", "model"))
    nmb = max(1, global_batch // target_microbatch)
    while global_batch % nmb:
        nmb -= 1
    return ElasticPlan(
        n_devices=mesh.size,
        mesh=mesh,
        per_device_batch=global_batch // data,
        num_microbatches=nmb,
    )


# ---------------------------------------------------------------------------
# Straggler mitigation: per-step timing watchdog
# ---------------------------------------------------------------------------

class StragglerWatchdog:
    """Tracks per-step (or per-host heartbeat) durations; flags outliers.

    On a real pod the flagged host is reported to the orchestrator which
    drains and replaces it; here the policy hook is injectable and the
    detection logic is unit-tested. Detection: a step is a straggler event
    if it exceeds ``factor`` × running median over the window; a host is
    flagged after ``patience`` consecutive events.
    """

    def __init__(self, window: int = 50, factor: float = 2.0,
                 patience: int = 3,
                 on_flag: Optional[Callable[[str, float], None]] = None):
        self.window = window
        self.factor = factor
        self.patience = patience
        self.on_flag = on_flag or (lambda host, t: None)
        self._times: list[float] = []
        self._consecutive: dict[str, int] = {}
        self.flagged: list[str] = []

    def median(self) -> Optional[float]:
        return statistics.median(self._times) if self._times else None

    def record(self, duration_s: float, host: str = "host0") -> bool:
        """Returns True if this step was a straggler event."""
        med = self.median()
        self._times.append(duration_s)
        if len(self._times) > self.window:
            self._times.pop(0)
        if med is None or len(self._times) < 5:
            return False
        if duration_s > self.factor * med:
            c = self._consecutive.get(host, 0) + 1
            self._consecutive[host] = c
            if c >= self.patience and host not in self.flagged:
                self.flagged.append(host)
                self.on_flag(host, duration_s)
            return True
        self._consecutive[host] = 0
        return False


# ---------------------------------------------------------------------------
# Preemption: SIGTERM → checkpoint-and-exit
# ---------------------------------------------------------------------------

class PreemptionHandler:
    """Installs a SIGTERM/SIGINT handler that raises a request flag; the
    train loop checks ``should_stop`` each step and checkpoints before
    exiting (TPU preemption notices give ~30 s)."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self._stop = False
        self._signals = signals
        self._old = {}

    def __enter__(self):
        for s in self._signals:
            self._old[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._old.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._stop = True

    @property
    def should_stop(self) -> bool:
        return self._stop


# ---------------------------------------------------------------------------
# Restartable step-runner glue (used by launch/train.py)
# ---------------------------------------------------------------------------

def run_with_restarts(step_fn, n_steps: int, ckpt, state, *, save_every: int,
                      start_step: int = 0, watchdog: StragglerWatchdog | None = None,
                      preempt: PreemptionHandler | None = None):
    """Drive step_fn(state)->state with periodic async checkpoints,
    straggler tracking, and preemption-safe exit. Returns (state, last_step)."""
    step = start_step
    while step < n_steps:
        t0 = time.perf_counter()
        state = step_fn(state)
        dt = time.perf_counter() - t0
        step += 1
        if watchdog is not None:
            watchdog.record(dt)
        if step % save_every == 0:
            ckpt.save(step, state, blocking=False)
        if preempt is not None and preempt.should_stop:
            ckpt.wait()
            ckpt.save(step, state, blocking=True)
            break
    ckpt.wait()
    return state, step
