"""Fault-injection harness for the solve pipeline (DESIGN.md §9).

The failure model's claims — per-slot isolation, bounded retries, truthful
statuses, finite answers — are only worth anything if they are *exercised*:
this module provides the injectors the chaos suite (``tests/test_faults.py``
and the CI chaos job) drives against the engine, the robust driver and the
serving layer. Fault classes:

* data faults — NaN rows / Inf entries in A or y (``inject_nan_row``,
  ``inject_inf_entry``), rank-deficient A (``rank_deficient_matrix``),
  κ ≈ 1e10 conditioning (``ill_conditioned_matrix``);
* sketch faults — adversarially-chosen sketch keys
  (``AdversarialKeyProvider``): the serving layer's key schedule is the
  DETERMINISTIC ``fold_in(base_key, req_id)``, so a key whose draw is bad
  for a given problem is reproducibly bad — the wrapper poisons exactly
  the slots whose key matches a black-list, emulating the worst-case draw
  for that schedule, and the retry driver's ``fold_in(key, attempt)``
  redraw is precisely what escapes it;
* infrastructure faults — simulated shard dropout: a
  ``BlockEmulationProvider(..., drop_shards=...)`` whose dropped shards
  contribute nothing to the level-Gram psum, the single-device emulation
  of a pod re-psumming over K−1 surviving data shards.

Everything here is build-time injection into otherwise-ordinary inputs;
nothing in this module is imported by the production path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.level_grams import BlockEmulationProvider, get_provider


# -- data faults -----------------------------------------------------------
def inject_nan_row(A: jnp.ndarray, problem: int, row: int = 0) -> jnp.ndarray:
    """Return A (B, n, d) with every entry of one problem's row set to NaN
    (a corrupted feature record)."""
    return A.at[problem, row, :].set(jnp.nan)


def inject_inf_entry(y: jnp.ndarray, problem: int, idx: int = 0,
                     sign: float = 1.0) -> jnp.ndarray:
    """Return y (B, n) with one target entry of one problem set to ±Inf
    (an overflowed label)."""
    return y.at[problem, idx].set(sign * jnp.inf)


def rank_deficient_matrix(key: jax.Array, n: int, d: int,
                          rank: int) -> jnp.ndarray:
    """(n, d) matrix of exact rank ``rank`` < d (duplicated factor columns:
    collinear features, the classic degenerate design)."""
    if not 0 < rank < d:
        raise ValueError(f"need 0 < rank < d, got rank={rank}, d={d}")
    L = jax.random.normal(key, (n, rank)) / jnp.sqrt(n)
    R = jax.random.normal(jax.random.fold_in(key, 1), (rank, d))
    return L @ R


def ill_conditioned_matrix(key: jax.Array, n: int, d: int,
                           cond: float = 1e10) -> jnp.ndarray:
    """(n, d) matrix with singular values log-spaced over κ = ``cond``."""
    ku, kv = jax.random.split(key)
    U, _ = jnp.linalg.qr(jax.random.normal(ku, (n, d)))
    V, _ = jnp.linalg.qr(jax.random.normal(kv, (d, d)))
    sv = jnp.logspace(0.0, -jnp.log10(cond), d)
    return (U * sv[None, :]) @ V.T


# -- sketch faults ---------------------------------------------------------
def _key_bits(keys: jax.Array) -> jnp.ndarray:
    """Raw uint32 bits for typed (jax.random.key) or legacy keys."""
    if jnp.issubdtype(keys.dtype, jax.dtypes.prng_key):
        return jax.random.key_data(keys)
    return keys


class AdversarialKeyProvider:
    """Level-Gram provider wrapper that NaN-poisons the sketch of exactly
    the problems whose per-problem key is on a black-list.

    This models the adversarial-draw threat for a *deterministic* key
    schedule (the serving layer derives slot keys as
    ``fold_in(base_key, req_id)``): an adversary who knows the schedule can
    craft a request whose assigned draw is catastrophically bad. Poisoning
    is lanewise over the batch axis of the (L, B, d, d) level Grams —
    neighbors' Grams are bit-identical to a clean pass, which is what the
    isolation assertions in the chaos suite check — and traceable (a key
    comparison under jit), so the wrapped provider runs inside the same
    compiled engine. A redrawn key (``fold_in(key, attempt)``, the retry
    driver) no longer matches the black-list: retries recover, exactly the
    designed escape hatch.
    """

    def __init__(self, inner, bad_keys: jax.Array):
        self.inner = get_provider(inner)
        bits = _key_bits(jnp.asarray(bad_keys))
        self._bad_bits = bits[None] if bits.ndim == 1 else bits  # (K, 2)
        self.name = f"adversarial[{self.inner.name}]"

    def sample(self, keys, m_max, n, dtype):
        bits = _key_bits(keys)                                   # (B, 2)
        hit = jnp.all(bits[:, None, :] == self._bad_bits[None, :, :],
                      axis=-1)                                   # (B, K)
        return {"inner": self.inner.sample(keys, m_max, n, dtype),
                "_poisoned": jnp.any(hit, axis=-1)}              # (B,)

    def level_grams(self, data, q, ladder, row_weights=None,
                    compute_dtype=None):
        g = self.inner.level_grams(data["inner"], q, ladder,
                                   row_weights=row_weights,
                                   compute_dtype=compute_dtype)  # (L, B, d, d)
        return jnp.where(data["_poisoned"][None, :, None, None],
                         jnp.nan, g)


# -- infrastructure faults -------------------------------------------------
def dropout_provider(inner, n_shards: int,
                     drop_shards: tuple[int, ...]) -> BlockEmulationProvider:
    """Block-sketch provider emulating a pod that lost ``drop_shards`` of
    its ``n_shards`` data shards and re-psums level Grams over the
    survivors (DESIGN.md §5/§9)."""
    return BlockEmulationProvider(inner, n_shards, drop_shards=drop_shards)


class ShardLossInjector:
    """Chaos hook for the segmented driver: kill shard ``shard`` at segment
    boundary ``at_segment`` (once), recombine the surviving per-shard
    ladder Grams by the cache's one-subtraction ``drop``, and hand the
    recombined (L, B, d, d) stack back so the driver
    ``reprecondition_padded``s mid-solve — the injected form of losing a
    data shard on a real pod. Pass as
    ``segmented_padded_solve_batched(on_segment=…)`` with a
    ``core.distributed.ShardLadderCache`` built before the solve."""

    def __init__(self, cache, *, shard: int, at_segment: int):
        self.cache = cache
        self.shard = shard
        self.at_segment = at_segment
        self.fired = False
        self.fired_at: int | None = None

    def __call__(self, segment: int, state):
        if self.fired or segment < self.at_segment:
            return None
        self.fired = True
        self.fired_at = segment
        return self.cache.drop(self.shard)
