"""Mesh-agnostic, atomic, resharding checkpoint manager.

Layout (one directory per step)::

    <dir>/step_000042/
        manifest.json         # tree structure, shapes, dtypes, metadata
        arrays/<leafpath>.npy # one file per leaf (host numpy)
        COMMITTED             # written LAST — presence marks a valid ckpt
    <dir>/step_000042.tmp/    # staging; atomic rename on commit

Properties needed at 1000+ nodes, implemented here and unit-tested:
* atomicity — partial writes never corrupt the latest checkpoint (staging
  dir + COMMITTED marker + atomic rename);
* resharding restore — leaves are stored as full logical arrays, restore
  places them onto ANY mesh/sharding (elastic shrink/grow, §elastic.py);
* keep-last-k GC;
* async save (background thread) so the train loop never blocks on IO;
* data-pipeline state and optimizer step are part of the manifest.

On a real multi-host pod each host writes only the shards it owns
(process-local filter below is a single `is_fully_addressable` check);
in this single-process container that filter is a no-op.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy cannot serialize bf16/f8 natively; store them as uint views and
# restore via the manifest's dtype string.
_EXT_DTYPES = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
               "float8_e5m2": np.uint8}


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            p.key if hasattr(p, "key") else str(getattr(p, "idx", p))
            for p in path
        )
        flat[key] = leaf
    return flat


class CheckpointManager:
    def __init__(self, directory: str | os.PathLike, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # -- save --------------------------------------------------------------
    def save(self, step: int, tree, *, extra: dict | None = None,
             blocking: bool = True):
        """Snapshot to host memory synchronously, write asynchronously
        unless blocking=True."""
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        if blocking:
            self._write(step, host_tree, extra or {})
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}),
                daemon=True,
            )
            self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _write(self, step: int, host_tree, extra: dict):
        final = self.dir / f"step_{step:09d}"
        tmp = self.dir / f"step_{step:09d}.tmp"
        if tmp.exists():
            shutil.rmtree(tmp)
        (tmp / "arrays").mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra, "time": time.time(),
                    "leaves": {}}
        treedef = jax.tree_util.tree_structure(host_tree)
        manifest["treedef"] = str(treedef)
        for key, arr in flat.items():
            fname = key.replace("/", "__") + ".npy"
            dtype_name = str(arr.dtype)
            to_store = (
                arr.view(_EXT_DTYPES[dtype_name])
                if dtype_name in _EXT_DTYPES else arr
            )
            np.save(tmp / "arrays" / fname, to_store)
            manifest["leaves"][key] = {
                "file": fname,
                "shape": list(arr.shape),
                "dtype": dtype_name,
            }
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=2))
        (tmp / "COMMITTED").write_text("ok")
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # -- restore -------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in sorted(self.dir.glob("step_*")):
            if p.suffix == ".tmp" or not (p / "COMMITTED").exists():
                continue
            out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None,
                shardings=None) -> tuple[Any, dict]:
        """Restore into the structure of ``tree_like`` (shapes validated).
        ``shardings``: optional matching pytree of NamedShardings — arrays
        are device_put onto them (this is the elastic resharding path).
        Returns (tree, extra)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {self.dir}")
        root = self.dir / f"step_{step:09d}"
        manifest = json.loads((root / "manifest.json").read_text())
        flat_like = _flatten(tree_like)
        flat_sh = _flatten(shardings) if shardings is not None else {}
        out_flat = {}
        for key, like in flat_like.items():
            meta = manifest["leaves"].get(key)
            if meta is None:
                raise KeyError(f"checkpoint missing leaf {key!r}")
            arr = np.load(root / "arrays" / meta["file"])
            if meta["dtype"] in _EXT_DTYPES:
                arr = arr.view(getattr(ml_dtypes, meta["dtype"]))
            if tuple(arr.shape) != tuple(like.shape):
                raise ValueError(
                    f"shape mismatch for {key}: ckpt {arr.shape} vs "
                    f"expected {tuple(like.shape)}"
                )
            arr = arr.astype(like.dtype)
            if key in flat_sh:
                out_flat[key] = jax.device_put(arr, flat_sh[key])
            else:
                out_flat[key] = jax.numpy.asarray(arr)
        # rebuild tree in tree_like's structure
        leaves_like, treedef = jax.tree_util.tree_flatten(tree_like)
        keys = list(_flatten(tree_like).keys())
        out_leaves = [out_flat[k] for k in keys]
        return jax.tree_util.tree_unflatten(treedef, out_leaves), manifest["extra"]
