from .checkpoint import CheckpointManager
from .resilience import (
    ElasticPlan,
    PreemptionHandler,
    StragglerWatchdog,
    plan_elastic,
    run_with_restarts,
)
