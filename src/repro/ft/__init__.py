from .checkpoint import CheckpointManager
from .faults import (
    AdversarialKeyProvider,
    ShardLossInjector,
    dropout_provider,
    ill_conditioned_matrix,
    inject_inf_entry,
    inject_nan_row,
    rank_deficient_matrix,
)
from .resilience import (
    ElasticPlan,
    PreemptionHandler,
    StragglerWatchdog,
    plan_elastic,
    run_with_restarts,
)
